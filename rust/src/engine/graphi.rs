//! The Graphi engine (§4–§5): centralized critical-path-first scheduler,
//! symmetric pinned executor fleet, per-executor SPSC buffers, light-weight
//! executor for tiny ops.
//!
//! This implementation runs the *actual* scheduling data structures (level
//! max-heap, idle bitmap with bit-scan, SPSC rings) against virtual time
//! from [`crate::sim`]: the only simulated quantity is how long each op
//! body takes on its thread team, priced by [`crate::cost::CostModel`].
//!
//! # Width-curve pricing (moldable ops)
//!
//! Under a [`WidthPlan`] an op may run as a **gang** of `w` executors —
//! the virtual-time mirror of the threaded fleet's gang formation
//! ([`crate::runtime::fleet`]). Three rules, both dispatch modes:
//!
//! * **Occupancy** — a width-`w` op holds `w` executors for its whole
//!   duration: the leader plus `w − 1` recruits, all marked busy and all
//!   freed by the op's single `Done` event. When fewer than `w` peers are
//!   idle the gang *shrinks* to whoever is available instead of waiting —
//!   exactly the threaded leader's no-deadlock fallback — so a width plan
//!   can reduce effective parallelism (fewer concurrent ops) but never
//!   stall the fleet.
//! * **Duration becomes `f(width)`** — the op body is priced as one fused
//!   `w × threads_per`-thread team through the same USL curve as scalar
//!   pricing ([`crate::cost::CostModel::gang_duration_us`]): sublinear
//!   gains up to the op's saturation point, the Fig-2 oversaturation tail
//!   past it. Wide GEMMs gain; small element-wise ops lose — which is the
//!   whole point of searching widths per op class.
//! * **Formation latency is scheduler time** — recruiting each peer costs
//!   [`crate::cost::Calibration::gang_recruit_us`], charged `(w − 1)×`
//!   per formed gang into `scheduler_busy_us` (it is dispatch work, not
//!   op work).
//!
//! Every width-plan branch is guarded behind `w > 1`: a `None` plan or a
//! uniform width-1 plan takes the exact pre-moldable code paths, RNG draw
//! order included, so width-free runs stay byte-identical.

use std::sync::Arc;

use crate::cost::Interference;
use crate::graph::op::{EwKind, OpKind};
use crate::graph::{levels, phase_members, width_phases, Graph, NodeId};
use crate::sim::topology::PlacementKind;
use crate::sim::{BandwidthArbiter, EventQueue, Placement};
use crate::util::rng::Rng;

use super::policies::Policy;
use super::ready::{entry_node, entry_width, pack_entry_wide, DepTracker, ReadySet, MAX_WIDTH};
use super::ring::SpscRing;
use super::scheduler::IdleBitmap;
use super::trace::{OpRecord, LIGHTWEIGHT_EXECUTOR};
use super::worksteal::{self, Acquire, DomainMap, WorkStealDeque};
use super::{DispatchMode, Engine, EngineMetrics, PhasePlan, RunResult, SimEnv, WidthPlan};

/// Configuration of the Graphi engine.
#[derive(Debug, Clone)]
pub struct GraphiEngine {
    /// Number of symmetric executors (§4.2).
    pub executors: usize,
    /// Threads per executor.
    pub threads_per: usize,
    /// Ready-op ordering (the paper: critical-path first).
    pub policy: Policy,
    /// Thread placement; Graphi's default is pinned tile-disjoint (§4.4).
    pub placement: PlacementKind,
    /// Use profiled duration estimates for level values (§4.2). When
    /// false, unit durations are used (structure-only levels) — an
    /// ablation showing the profiler's contribution.
    pub profiled_levels: bool,
    /// Externally measured per-op durations (µs) for the level
    /// computation — what the profiler/autotuner feeds back (§4.2). Takes
    /// precedence over `profiled_levels`; must cover every node.
    pub duration_overrides: Option<std::sync::Arc<[f64]>>,
    /// Write element-wise outputs with non-temporal stream stores (§6).
    pub stream_stores: bool,
    /// §6 cache-affinity attempt: remember the producing executor as the
    /// *preferred executor* for each triggered op and dispatch there when
    /// idle; element-wise ops get a warm-L2 discount on a hit. The paper
    /// found only a modest element-wise gain and kept it off; we keep it
    /// as an ablation.
    pub locality: bool,
    /// Fault injection: `(executor, slowdown)` — that executor runs every
    /// op `slowdown`× slower (straggler/thermal-throttle study).
    pub straggler: Option<(usize, f64)>,
    /// Completion-resolution architecture. `Centralized` is the paper's
    /// §4/§5 design (and the default); `Decentralized` mirrors the
    /// executor-side resolution + CP-aware work stealing of
    /// [`crate::runtime::threaded`] in virtual time, so the autotuner can
    /// search over dispatch mode as a candidate axis.
    pub dispatch: DispatchMode,
    /// Per-phase dispatch assignment (overrides `dispatch`): the graph's
    /// width phases run sequentially, each under its own mode, with a
    /// barrier at every boundary. `None` = the uniform `dispatch` mode
    /// for the whole graph.
    pub phase_plan: Option<PhasePlan>,
    /// Moldable widths: per-op-class gang sizes (see the module docs'
    /// width-curve pricing section). `None` — and the uniform width-1
    /// plan — run the exact width-free code paths, byte for byte.
    pub width_plan: Option<WidthPlan>,
}

impl GraphiEngine {
    /// The paper's default configuration for a given fleet shape.
    pub fn new(executors: usize, threads_per: usize) -> GraphiEngine {
        GraphiEngine {
            executors,
            threads_per,
            policy: Policy::CriticalPathFirst,
            placement: PlacementKind::PinnedDisjoint,
            profiled_levels: true,
            duration_overrides: None,
            stream_stores: true,
            locality: false,
            straggler: None,
            dispatch: DispatchMode::Centralized,
            phase_plan: None,
            width_plan: None,
        }
    }

    pub fn with_policy(mut self, policy: Policy) -> GraphiEngine {
        self.policy = policy;
        self
    }

    pub fn with_dispatch(mut self, dispatch: DispatchMode) -> GraphiEngine {
        self.dispatch = dispatch;
        self
    }

    pub fn with_phase_plan(mut self, plan: PhasePlan) -> GraphiEngine {
        self.phase_plan = Some(plan);
        self
    }

    /// Schedule with per-op-class moldable widths (gang scheduling).
    pub fn with_width_plan(mut self, plan: WidthPlan) -> GraphiEngine {
        self.width_plan = Some(plan);
        self
    }

    /// Schedule with levels derived from profiled per-op durations (the
    /// autotuner's duration table) instead of the analytic cost model.
    pub fn with_profiled_durations(
        mut self,
        durations: impl Into<std::sync::Arc<[f64]>>,
    ) -> GraphiEngine {
        self.duration_overrides = Some(durations.into());
        self
    }
}

enum Ev {
    /// Op finished on a worker executor. `gang` is the op's recruited
    /// peer executors (empty for width-1 ops): they were busy for the
    /// op's whole duration and are freed by this one event, mirroring the
    /// threaded gang members' done-handshake with their leader.
    Done { node: NodeId, exec: u32, bw_token: u64, gang: Vec<u32> },
    /// Op finished on the light-weight executor.
    DoneLightweight { node: NodeId },
}

struct Sim<'a> {
    graph: &'a Graph,
    env: &'a SimEnv,
    cfg: &'a GraphiEngine,
    interference: Interference,
    rng: Rng,
    q: EventQueue<Ev>,
    deps: DepTracker,
    ready: ReadySet,
    /// The level values behind `ready`'s ordering — shared out so the
    /// decentralized path can pack deque keys from the same priorities.
    levels: Arc<[f64]>,
    idle: IdleBitmap,
    rings: Vec<SpscRing<NodeId>>,
    bw: BandwidthArbiter,
    placement: Placement,
    /// Per-executor NUMA factor (SNC modes): spanning executors pay
    /// `numa_span_penalty` on memory-bound ops, contained ones enjoy
    /// `numa_local_boost` (§9 future-work feature).
    numa_factor: Vec<f64>,
    /// Per-node memory-boundedness at this team size (cached).
    mem_bound: Vec<bool>,
    /// Cached cost-model durations at this fleet's team size (§Perf L3
    /// iteration 2: duration_us was being evaluated three times per op —
    /// levels, dispatch, bandwidth demand; caching once gives ~2× sim
    /// throughput).
    base_dur_us: Vec<f64>,
    /// Per-node gang-width *target* under the engine's width plan: the
    /// plan's class width clamped to the fleet, Tiny forced to 1. All-ones
    /// when there is no plan (or the identity plan), which disables every
    /// gang branch.
    width_of: Vec<u32>,
    /// §6 locality: preferred executor per node (the producer of its input).
    preferred: Vec<Option<u8>>,
    sched_free_us: f64,
    lw_free_us: f64,
    ready_at: Vec<f64>,
    records: Vec<OpRecord>,
    metrics: EngineMetrics,
}

impl<'a> Sim<'a> {
    fn new(graph: &'a Graph, env: &'a SimEnv, cfg: &'a GraphiEngine) -> Sim<'a> {
        let cost = &env.cost;
        let placement = match cfg.placement {
            PlacementKind::PinnedDisjoint => {
                Placement::pinned_disjoint(&cost.machine, cfg.executors, cfg.threads_per)
                    .expect("invalid executor configuration")
            }
            PlacementKind::PinnedSharedTiles => {
                Placement::pinned_shared_tiles(&cost.machine, cfg.executors, cfg.threads_per)
                    .expect("invalid executor configuration")
            }
            PlacementKind::OsManaged => Placement::os_managed(cfg.executors),
        };
        // §4.2: the profiler estimates per-op durations at the chosen team
        // size; levels derive from those estimates. Static per-node factors
        // (stream stores §6, shared-L2 placement) are folded in here once
        // (§Perf L3 iteration 3) — only stochastic interference remains in
        // the dispatch path.
        let shared_tiles = cfg.placement == PlacementKind::PinnedSharedTiles
            && placement.any_tile_sharing();
        let interference_static = Interference::new(cost.cal.clone());
        let base_dur_us: Vec<f64> = graph
            .nodes()
            .iter()
            .map(|n| {
                let mut dur = cost.duration_us(&n.kind, cfg.threads_per);
                if cfg.stream_stores {
                    if let OpKind::Elementwise { arity, kind: ek, .. } = &n.kind {
                        if *ek != EwKind::Copy && cost.memory_bound(&n.kind, cfg.threads_per) {
                            let out_frac = 1.0 / (*arity as f64 + 1.0);
                            dur *= 1.0 - cost.cal.stream_store_saving * out_frac;
                        }
                    }
                }
                if shared_tiles {
                    dur *= interference_static.l2_overlap_factor(true);
                }
                dur
            })
            .collect();
        let level_values: Arc<[f64]> = if let Some(overrides) = &cfg.duration_overrides {
            assert_eq!(
                overrides.len(),
                graph.len(),
                "duration overrides must cover every node"
            );
            levels(graph, overrides)
        } else if cfg.profiled_levels {
            levels(graph, &base_dur_us)
        } else {
            levels(graph, &vec![1.0; graph.len()])
        }
        .into();
        let numa_factor: Vec<f64> = (0..cfg.executors)
            .map(|e| {
                if cost.machine.numa_domains <= 1 {
                    1.0
                } else if placement.executor_spans_domains(&cost.machine, e) {
                    cost.cal.numa_span_penalty
                } else {
                    cost.cal.numa_local_boost
                }
            })
            .collect();
        let mem_bound: Vec<bool> = graph
            .nodes()
            .iter()
            .map(|n| cost.memory_bound(&n.kind, cfg.threads_per))
            .collect();
        let width_of: Vec<u32> = match &cfg.width_plan {
            Some(plan) if !plan.is_uniform_one() => graph
                .nodes()
                .iter()
                .map(|n| {
                    if n.kind.is_tiny() {
                        1
                    } else {
                        plan.width_for(n.kind.class()).min(cfg.executors as u32).min(MAX_WIDTH)
                    }
                })
                .collect(),
            _ => vec![1; graph.len()],
        };
        Sim {
            graph,
            env,
            cfg,
            interference: env.interference(),
            rng: env.rng(),
            q: EventQueue::new(),
            deps: DepTracker::new(graph),
            ready: ReadySet::new(cfg.policy, Arc::clone(&level_values), env.seed ^ 0x5EED),
            levels: level_values,
            idle: IdleBitmap::new(cfg.executors),
            rings: (0..cfg.executors).map(|_| SpscRing::new(1)).collect(),
            bw: BandwidthArbiter::new(cost.machine.mcdram_bw),
            placement,
            numa_factor,
            mem_bound,
            base_dur_us,
            width_of,
            preferred: vec![None; graph.len()],
            sched_free_us: 0.0,
            lw_free_us: 0.0,
            ready_at: vec![0.0; graph.len()],
            records: Vec::with_capacity(graph.len()),
            metrics: EngineMetrics {
                executor_busy_us: vec![0.0; cfg.executors],
                ..Default::default()
            },
        }
    }

    /// Simulated duration of an op body on this engine's executors.
    /// Static factors (stream stores, shared-L2) are pre-folded into
    /// `base_dur_us`; only stochastic interference is applied here.
    fn op_duration(&mut self, node: NodeId, executor: usize, locality_hit: bool) -> f64 {
        let cost = &self.env.cost;
        let mut dur = self.base_dur_us[node as usize];
        // SNC modes: memory-bound ops feel the executor's domain placement
        if self.mem_bound[node as usize] {
            dur *= self.numa_factor[executor];
        }
        if self.placement.kind == PlacementKind::OsManaged {
            let total = self.cfg.executors * self.cfg.threads_per;
            dur *= self
                .interference
                .unpinned_factor(total, cost.machine.cores, &mut self.rng);
            dur += self.interference.migration_stall_us(&mut self.rng);
        }
        // §6: warm-L2 hit helps element-wise ops only ("matrix
        // multiplications did not improve" — MKL's blocking defeats it)
        if locality_hit {
            if let OpKind::Elementwise { .. } = self.graph.node(node).kind {
                dur *= 1.0 - cost.cal.locality_ew_saving;
            }
        }
        if let Some((straggler, factor)) = self.cfg.straggler {
            if straggler == executor {
                dur *= factor;
            }
        }
        dur * self.interference.noise(&mut self.rng)
    }

    /// Duration multiplier when `node` runs as one fused gang of `w > 1`
    /// executors: the USL curve at `w × threads_per` threads relative to
    /// the solo team ([`crate::cost::CostModel::gang_duration_us`]).
    /// Multiplicative so the static per-node folds in `base_dur_us`
    /// (stream stores, shared-L2) are preserved.
    fn gang_stretch(&self, node: NodeId, w: u32) -> f64 {
        debug_assert!(w > 1);
        let cost = &self.env.cost;
        let kind = &self.graph.node(node).kind;
        let solo = cost.duration_us(kind, self.cfg.threads_per);
        if solo <= 0.0 {
            return 1.0;
        }
        cost.gang_duration_us(kind, w as usize, self.cfg.threads_per) / solo
    }

    /// Dispatch loop (§4.3, Algorithm 1): pop max-level ready ops and push
    /// them to idle executors' buffers; tiny ops go to the light-weight
    /// executor.
    fn dispatch(&mut self, now: f64) {
        loop {
            if self.ready.is_empty() {
                return;
            }
            // Peek-free design: tiny ops never consume an executor slot, so
            // pop first and route.
            let Some(node) = ({
                if self.idle.any_idle() {
                    self.ready.pop()
                } else {
                    // executors full: still drain tiny ops to the LW lane
                    None
                }
            }) else {
                return;
            };
            let kind = &self.graph.node(node).kind;
            if kind.is_tiny() {
                // §5.2: bootstrap/small ops run on the reserved
                // light-weight single-threaded executor.
                let start = self.lw_free_us.max(now);
                let dur = self.env.cost.cal.tiny_op_us * self.interference.noise(&mut self.rng);
                self.lw_free_us = start + dur;
                self.metrics.lightweight_ops += 1;
                self.metrics.queue_wait_us += start - self.ready_at[node as usize];
                self.records.push(OpRecord {
                    node,
                    executor: LIGHTWEIGHT_EXECUTOR,
                    start_us: start,
                    end_us: start + dur,
                });
                self.q.schedule(start + dur, Ev::DoneLightweight { node });
                continue;
            }
            // §6 locality: prefer the executor that produced this op's
            // input if it is idle; otherwise the first idle (bit-scan).
            let preferred = self.preferred[node as usize].map(|p| p as usize);
            let (e, locality_hit) = match preferred {
                Some(p) if self.cfg.locality && self.idle.is_idle(p) => (p, true),
                _ => (self.idle.first_idle().expect("checked any_idle"), false),
            };
            self.idle.set_busy(e);
            // moldable gang: the leader recruits up to `w − 1` idle peers,
            // shrinking to whoever is available rather than waiting (the
            // threaded leader's no-deadlock fallback)
            let mut gang: Vec<u32> = Vec::new();
            let w_target = self.width_of[node as usize];
            if w_target > 1 {
                while (gang.len() as u32) < w_target - 1 {
                    match self.idle.first_idle() {
                        Some(m) => {
                            self.idle.set_busy(m);
                            gang.push(m as u32);
                        }
                        None => break,
                    }
                }
            }
            // scheduler decision cost: heap pop + bitmap scan + ring push,
            // serialized on the scheduler thread; evaluated once so the
            // busy-time metric and the timeline can never disagree
            let mut dispatch_cost_us = self.interference.graphi_dispatch_us();
            if !gang.is_empty() {
                // gang-formation latency is scheduler time: one recruit
                // handshake per peer
                dispatch_cost_us += self.env.cost.cal.gang_recruit_us * gang.len() as f64;
                self.metrics.gangs_formed += 1;
                self.metrics.gang_recruits += gang.len() as u64;
            }
            self.sched_free_us = self.sched_free_us.max(now) + dispatch_cost_us;
            self.metrics.scheduler_busy_us += dispatch_cost_us;
            self.metrics.dispatches += 1;
            // hand off through the executor's real SPSC ring
            self.rings[e]
                .push(node)
                .expect("ring depth 1, executor idle ⇒ empty");
            let start = self.sched_free_us;
            let fetched = self.rings[e].pop().expect("just pushed");
            debug_assert_eq!(fetched, node);
            let mut dur = self.op_duration(node, e, locality_hit);
            if !gang.is_empty() {
                dur *= self.gang_stretch(node, 1 + gang.len() as u32);
            }
            let demand = {
                let base = self.base_dur_us[node as usize];
                if base > 0.0 { self.graph.node(node).kind.bytes() / (base * 1e-6) } else { 0.0 }
            };
            let (stretch, token) = self.bw.admit(demand);
            dur *= stretch;
            self.metrics.queue_wait_us += start - self.ready_at[node as usize];
            self.metrics.executor_busy_us[e] += dur;
            for &m in &gang {
                self.metrics.executor_busy_us[m as usize] += dur;
            }
            self.records.push(OpRecord { node, executor: e as u32, start_us: start, end_us: start + dur });
            self.q.schedule(start + dur, Ev::Done { node, exec: e as u32, bw_token: token, gang });
        }
    }

    fn run(mut self) -> RunResult {
        for s in self.deps.sources() {
            self.ready_at[s as usize] = 0.0;
            self.ready.push(s);
        }
        self.dispatch(0.0);
        let mut makespan = 0.0f64;
        while let Some((t, ev)) = self.q.pop() {
            makespan = makespan.max(t);
            match ev {
                Ev::Done { node, exec, bw_token, gang } => {
                    self.idle.set_idle(exec as usize);
                    for &m in &gang {
                        self.idle.set_idle(m as usize);
                    }
                    self.bw.release(bw_token);
                    let ready_at = &mut self.ready_at;
                    let ready = &mut self.ready;
                    let preferred = &mut self.preferred;
                    let locality = self.cfg.locality;
                    self.deps.complete(self.graph, node, |n| {
                        ready_at[n as usize] = t;
                        if locality {
                            preferred[n as usize] = Some(exec as u8);
                        }
                        ready.push(n);
                    });
                }
                Ev::DoneLightweight { node } => {
                    let ready_at = &mut self.ready_at;
                    let ready = &mut self.ready;
                    self.deps.complete(self.graph, node, |n| {
                        ready_at[n as usize] = t;
                        ready.push(n);
                    });
                }
            }
            self.dispatch(t);
        }
        assert!(self.deps.is_done(), "simulation drained with unexecuted ops");
        RunResult { makespan_us: makespan, records: self.records, metrics: self.metrics }
    }

    /// Per-executor NUMA-domain map for topology-aware victim ranking:
    /// each executor lives in the domain of its team's first core (its
    /// deque's home). OS-managed placements have no known cores — the map
    /// degrades to flat, i.e. domain-blind ranking.
    fn domain_map(&self) -> DomainMap {
        let machine = &self.env.cost.machine;
        let domains: Vec<u32> = (0..self.cfg.executors)
            .map(|e| {
                self.placement
                    .cores
                    .get(e)
                    .and_then(|team| team.first())
                    .map(|&c0| machine.domain_of_core(c0) as u32)
                    .unwrap_or(0)
            })
            .collect();
        DomainMap::new(domains, 0)
    }

    /// Decentralized mode in virtual time — the same architecture as
    /// [`crate::runtime::threaded`]'s decentralized path, over the *real*
    /// [`WorkStealDeque`]s (exercised single-threaded here). There is no
    /// central scheduler and no light-weight lane: the executor finishing
    /// an op pays the successor-resolution cost itself (`queue_base_us`
    /// per triggered successor — one `fetch_sub` + deque push), a local
    /// pop costs `queue_base_us`, a steal adds the CAS premium
    /// `queue_cas_us`, and a *cross-domain* steal (SNC modes) additionally
    /// pays `steal_cross_domain_us` for the mesh crossing — which is why
    /// victim ranking prefers same-domain victims
    /// ([`worksteal::steal_highest_numa`]) and why the autotuner's search
    /// sees the preference pay off. All of it lands in
    /// `scheduler_busy_us`: it is scheduling work, merely spread across
    /// executors instead of serialized on one reserved core.
    fn run_decentralized(mut self) -> RunResult {
        let n_exec = self.cfg.executors;
        let pop_us = self.env.cost.cal.queue_base_us;
        let steal_us = self.env.cost.cal.queue_base_us + self.env.cost.cal.queue_cas_us;
        let cross_us = steal_us + self.env.cost.cal.steal_cross_domain_us;
        let domains = self.domain_map();
        let deques: Vec<WorkStealDeque> =
            (0..n_exec).map(|_| WorkStealDeque::new(self.graph.len())).collect();
        let mut exec_idle = vec![true; n_exec];
        let shared_levels = Arc::clone(&self.levels);
        let mut sources = self.deps.sources();
        // deque keys carry the op's gang width, like the threaded fleet's
        // packed entries; width 1 packs bit-identically to the plain key
        sources.sort_unstable_by_key(|&s| {
            pack_entry_wide(shared_levels[s as usize], s, self.width_of[s as usize])
        });
        for (i, &s) in sources.iter().enumerate() {
            self.ready_at[s as usize] = 0.0;
            deques[i % n_exec]
                .push(pack_entry_wide(shared_levels[s as usize], s, self.width_of[s as usize]))
                .expect("deque sized for the whole graph");
        }
        self.acquire_sweep(&deques, &domains, &mut exec_idle, 0, 0.0, [pop_us, steal_us, cross_us]);
        let mut makespan = 0.0f64;
        // one reusable resolution buffer for the whole run, like the
        // threaded executors' per-thread `batch`
        let mut batch: Vec<u64> = Vec::new();
        while let Some((t, ev)) = self.q.pop() {
            makespan = makespan.max(t);
            let Ev::Done { node, exec, bw_token, gang } = ev else {
                unreachable!("decentralized mode schedules only worker completions")
            };
            self.bw.release(bw_token);
            let e = exec as usize;
            // released gang members go idle and rejoin the sweep below
            for &m in &gang {
                exec_idle[m as usize] = true;
            }
            // the tentpole, in virtual time: the completing executor
            // resolves successors itself and pushes them onto its own
            // deque, ascending so the LIFO end is the batch's hottest op
            batch.clear();
            {
                let graph = self.graph;
                let ready_at = &mut self.ready_at;
                let levels = &shared_levels;
                let width_of = &self.width_of;
                self.deps.complete(graph, node, |s| {
                    ready_at[s as usize] = t;
                    batch.push(pack_entry_wide(levels[s as usize], s, width_of[s as usize]));
                });
            }
            let resolve_us = pop_us * batch.len() as f64;
            self.metrics.scheduler_busy_us += resolve_us;
            batch.sort_unstable();
            for &k in &batch {
                deques[e].push(k).expect("deque sized for the whole graph");
            }
            exec_idle[e] = true;
            // the completing executor gets first dibs (cache-warm LIFO
            // pop), then every idle executor steals what is exposed
            self.acquire_sweep(
                &deques,
                &domains,
                &mut exec_idle,
                e,
                t + resolve_us,
                [pop_us, steal_us, cross_us],
            );
        }
        assert!(self.deps.is_done(), "simulation drained with unexecuted ops");
        RunResult { makespan_us: makespan, records: self.records, metrics: self.metrics }
    }

    /// Let every idle executor acquire work (own-deque pop, else the
    /// domain-preferring highest-priority steal) until no idle executor
    /// finds any, starting the scan at `first`. `overheads` prices the
    /// three acquisition kinds `[local pop, same-domain steal,
    /// cross-domain steal]`.
    fn acquire_sweep(
        &mut self,
        deques: &[WorkStealDeque],
        domains: &DomainMap,
        exec_idle: &mut [bool],
        first: usize,
        now: f64,
        overheads: [f64; 3],
    ) {
        let n = deques.len();
        loop {
            let mut progressed = false;
            for i in 0..n {
                let e = (first + i) % n;
                if !exec_idle[e] {
                    continue;
                }
                if let Some((key, kind)) = worksteal::acquire_numa(deques, e, domains) {
                    let overhead = match kind {
                        Acquire::LocalPop => overheads[0],
                        Acquire::StealLocalDomain => overheads[1],
                        Acquire::StealCrossDomain => overheads[2],
                    };
                    if kind.is_steal() {
                        self.metrics.steals += 1;
                        if kind == Acquire::StealCrossDomain {
                            self.metrics.steals_cross_domain += 1;
                        }
                    }
                    exec_idle[e] = false;
                    // moldable gang: the acquiring executor leads; idle
                    // peers fuse into its team instead of sweeping for
                    // their own work (shrink-don't-wait on a shortfall)
                    let w_target = entry_width(key);
                    let mut gang: Vec<u32> = Vec::new();
                    if w_target > 1 {
                        for off in 1..n {
                            if gang.len() as u32 >= w_target - 1 {
                                break;
                            }
                            let cand = (e + off) % n;
                            if exec_idle[cand] {
                                exec_idle[cand] = false;
                                gang.push(cand as u32);
                            }
                        }
                    }
                    self.launch_decentral(e, entry_node(key), now, overhead, gang);
                    progressed = true;
                }
            }
            if !progressed {
                return;
            }
        }
    }

    /// Start `node` on executor `e` at `now + overhead_us` (decentralized
    /// mode; no LW lane — every op runs on a worker executor). A non-empty
    /// `gang` fuses those peers into the op's team: recruit handshakes are
    /// extra acquisition overhead, the body runs on the wider team's USL
    /// curve, and every member stays busy until the op's single Done.
    fn launch_decentral(&mut self, e: usize, node: NodeId, now: f64, overhead_us: f64, gang: Vec<u32>) {
        let mut overhead_us = overhead_us;
        if !gang.is_empty() {
            overhead_us += self.env.cost.cal.gang_recruit_us * gang.len() as f64;
            self.metrics.gangs_formed += 1;
            self.metrics.gang_recruits += gang.len() as u64;
        }
        let start = now + overhead_us;
        self.metrics.scheduler_busy_us += overhead_us;
        self.metrics.dispatches += 1;
        let mut dur = self.op_duration(node, e, false);
        if !gang.is_empty() {
            dur *= self.gang_stretch(node, 1 + gang.len() as u32);
        }
        let demand = {
            let base = self.base_dur_us[node as usize];
            if base > 0.0 { self.graph.node(node).kind.bytes() / (base * 1e-6) } else { 0.0 }
        };
        let (stretch, token) = self.bw.admit(demand);
        dur *= stretch;
        self.metrics.queue_wait_us += start - self.ready_at[node as usize];
        self.metrics.executor_busy_us[e] += dur;
        for &m in &gang {
            self.metrics.executor_busy_us[m as usize] += dur;
        }
        self.records.push(OpRecord { node, executor: e as u32, start_us: start, end_us: start + dur });
        self.q.schedule(start + dur, Ev::Done { node, exec: e as u32, bw_token: token, gang });
    }
}

impl GraphiEngine {
    /// Execute a [`PhasePlan`]: each width phase runs as an induced
    /// subgraph under its own dispatch mode, phases strictly in sequence
    /// (safe — a node's predecessors are never in a later phase), records
    /// and metrics merged onto one timeline. The per-phase makespans sum:
    /// the barrier is the price the plan pays, and the autotuner only
    /// adopts a plan whose measured total still beats the uniform winner.
    fn run_phased(&self, graph: &Graph, env: &SimEnv, plan: &PhasePlan) -> RunResult {
        let phases = width_phases(graph, plan.threshold);
        assert_eq!(
            plan.modes.len(),
            phases.len(),
            "phase plan ({} modes) does not line up with the graph ({} phases at threshold {})",
            plan.modes.len(),
            phases.len(),
            plan.threshold
        );
        let members = phase_members(graph, &phases);
        let mut offset = 0.0f64;
        let mut records: Vec<OpRecord> = Vec::with_capacity(graph.len());
        let mut metrics = EngineMetrics {
            executor_busy_us: vec![0.0; self.executors],
            mode_switches: plan.mode_switches(),
            ..Default::default()
        };
        for (k, (mode, keep)) in plan.modes.iter().zip(&members).enumerate() {
            let (sub, map) = graph.induced_subgraph(keep);
            let sub_overrides: Option<std::sync::Arc<[f64]>> = self
                .duration_overrides
                .as_ref()
                .map(|d| map.iter().map(|&v| d[v as usize]).collect::<Vec<f64>>().into());
            let sub_engine = GraphiEngine {
                dispatch: *mode,
                phase_plan: None,
                duration_overrides: sub_overrides,
                ..self.clone()
            };
            // independent noise draws per phase, deterministic per seed
            let env_k = SimEnv { cost: env.cost.clone(), seed: env.seed ^ ((k as u64 + 1) << 48) };
            let r = sub_engine.run(&sub, &env_k);
            for rec in r.records {
                records.push(OpRecord {
                    node: map[rec.node as usize],
                    executor: rec.executor,
                    start_us: rec.start_us + offset,
                    end_us: rec.end_us + offset,
                });
            }
            offset += r.makespan_us;
            metrics.dispatches += r.metrics.dispatches;
            metrics.queue_wait_us += r.metrics.queue_wait_us;
            metrics.scheduler_busy_us += r.metrics.scheduler_busy_us;
            metrics.contention_us += r.metrics.contention_us;
            metrics.lightweight_ops += r.metrics.lightweight_ops;
            metrics.steals += r.metrics.steals;
            metrics.steals_cross_domain += r.metrics.steals_cross_domain;
            metrics.gangs_formed += r.metrics.gangs_formed;
            metrics.gang_recruits += r.metrics.gang_recruits;
            for (acc, busy) in metrics.executor_busy_us.iter_mut().zip(&r.metrics.executor_busy_us)
            {
                *acc += busy;
            }
        }
        RunResult { makespan_us: offset, records, metrics }
    }
}

/// How a simulated session ended — the simulator twin of the threaded
/// fleet's terminal states ([`crate::runtime::fleet`]'s
/// `Done` / `Failed` / `Cancelled` / `DeadlineExceeded`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SimSessionOutcome {
    Completed,
    /// The op at `node` (session-local id) panicked when it started.
    Failed { node: NodeId },
    Cancelled,
    DeadlineExceeded,
    /// The request was rejected at (virtual) admission and never ran —
    /// the simulated twin of `SessionError::Shed`
    /// ([`GraphiEngine::run_open_loop`]).
    Shed,
}

/// Fault model for one session of
/// [`GraphiEngine::run_concurrent_faulty`]: the simulated analogue of a
/// `FaultPlan` plus deadline — at most the *earliest* event fires, exactly
/// like the fleet's first-terminal-transition-wins latch.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SimFault {
    /// This node's op panics at its (virtual) start time.
    pub panic_at: Option<NodeId>,
    /// The client cancels the session at this virtual time, µs.
    pub cancel_at_us: Option<f64>,
    /// The session's deadline, µs past its t = 0 admission.
    pub deadline_us: Option<f64>,
}

/// One session's share of a multi-graph ([`GraphiEngine::run_concurrent`])
/// simulation: its records in *local* node ids, the virtual time at
/// which it quiesced (last op end for completed sessions; fault
/// observation joined with in-flight op drain for terminated ones), and
/// how it ended.
#[derive(Debug, Clone)]
pub struct SessionSimResult {
    pub records: Vec<OpRecord>,
    pub makespan_us: f64,
    pub outcome: SimSessionOutcome,
}

impl GraphiEngine {
    /// Serve-mode mirror: execute `N` independent DAGs **concurrently on
    /// one virtual fleet**, under the same pricing as [`Engine::run`].
    ///
    /// Implementation: the sessions' disjoint union
    /// ([`Graph::disjoint_union`]) is one DAG whose components do not
    /// interact, so running the ordinary engine on the union *is*
    /// multi-session scheduling — every scheduler structure (ready heap or
    /// deques, rings, LW lane, NUMA victim ranking, bandwidth arbiter) sees
    /// the interleaved entries of all sessions, and critical-path levels
    /// computed on the union equal each graph's own levels, which makes
    /// cross-session CP-first ordering the ordinary level comparison —
    /// the same approximation the threaded fleet's packed session keys
    /// make ([`crate::runtime::fleet`]). This keeps serve-mode scheduling
    /// differentially testable against real threads
    /// (`tests/serve_sessions.rs`): both must produce, per session, the
    /// full op set in a dependency-valid order.
    ///
    /// Returns the union-level result (fleet totals: makespan, steals,
    /// dispatches…) plus the per-session split of the trace.
    pub fn run_concurrent(
        &self,
        graphs: &[&Graph],
        env: &SimEnv,
    ) -> (RunResult, Vec<SessionSimResult>) {
        let faults = vec![SimFault::default(); graphs.len()];
        self.run_concurrent_faulty(graphs, env, &faults)
    }

    /// [`run_concurrent`](Self::run_concurrent) with per-session fault
    /// models — the simulator mirror of the threaded fleet's fault
    /// domains, so serve-mode fault handling stays differentially
    /// testable without real threads.
    ///
    /// The model matches the fleet's **lazy discard** semantics: the
    /// healthy union schedule is computed first, then each faulty session
    /// is truncated at its earliest fault event `t` — ops that started
    /// before `t` run to completion (they had already been popped), every
    /// later op is discarded, and the session's `makespan_us` becomes the
    /// quiescence time `max(t, end of in-flight ops)`. The union-level
    /// [`RunResult`] stays the counterfactual healthy run (fault-free
    /// totals), mirroring how fleet counters keep counting through
    /// faults.
    pub fn run_concurrent_faulty(
        &self,
        graphs: &[&Graph],
        env: &SimEnv,
        faults: &[SimFault],
    ) -> (RunResult, Vec<SessionSimResult>) {
        assert!(!graphs.is_empty(), "run_concurrent needs at least one graph");
        assert_eq!(graphs.len(), faults.len(), "one fault model per session");
        assert!(
            self.phase_plan.is_none(),
            "phase plans are derived per graph; a union of sessions has no single phase structure"
        );
        assert!(
            self.duration_overrides.is_none(),
            "duration overrides are per graph; profile the union instead"
        );
        assert!(
            self.width_plan.is_none(),
            "width plans are tuned per graph; the threaded fleet applies them in serve mode"
        );
        let (union, origin) = Graph::disjoint_union(graphs);
        let result = self.run(&union, env);
        let mut sessions: Vec<SessionSimResult> = graphs
            .iter()
            .map(|_| SessionSimResult {
                records: Vec::new(),
                makespan_us: 0.0,
                outcome: SimSessionOutcome::Completed,
            })
            .collect();
        for rec in &result.records {
            let (si, local) = origin[rec.node as usize];
            let session = &mut sessions[si];
            session.makespan_us = session.makespan_us.max(rec.end_us);
            session.records.push(OpRecord {
                node: local,
                executor: rec.executor,
                start_us: rec.start_us,
                end_us: rec.end_us,
            });
        }
        for (session, fault) in sessions.iter_mut().zip(faults) {
            // earliest event wins, like the fleet's terminal CAS latch
            let mut cut: Option<(f64, SimSessionOutcome)> = None;
            if let Some(n) = fault.panic_at {
                if let Some(rec) = session.records.iter().find(|r| r.node == n) {
                    cut = Some((rec.start_us, SimSessionOutcome::Failed { node: n }));
                }
            }
            if let Some(t) = fault.deadline_us {
                if session.makespan_us > t && cut.map_or(true, |(c, _)| t < c) {
                    cut = Some((t, SimSessionOutcome::DeadlineExceeded));
                }
            }
            if let Some(t) = fault.cancel_at_us {
                if session.makespan_us > t && cut.map_or(true, |(c, _)| t < c) {
                    cut = Some((t, SimSessionOutcome::Cancelled));
                }
            }
            if let Some((t, outcome)) = cut {
                // lazy discard: in-flight ops (started before t) drain,
                // nothing else is ever popped
                session.records.retain(|r| r.start_us < t);
                session.makespan_us =
                    session.records.iter().fold(t, |m, r| m.max(r.end_us));
                session.outcome = outcome;
            }
        }
        (result, sessions)
    }
}

/// One request of an open-loop simulated arrival trace
/// ([`GraphiEngine::run_open_loop`]): when it arrives, what it charges
/// against the admission budget, and how it is keyed by the non-FIFO
/// admission policies.
#[derive(Debug, Clone, Copy)]
pub struct SimArrival {
    /// Virtual arrival time, µs. Traces must be in nondecreasing `at_us`
    /// order — arrival order *is* the FIFO ticket order.
    pub at_us: f64,
    /// §5.1 bytes charged against the budget from admission to quiescence.
    pub bytes: u64,
    /// Priority class, 0 = most urgent (`AdmissionPolicy::Priority`).
    pub class: u8,
    /// Max admission wait before the request is shed; doubles as the EDF
    /// deadline key. `None` waits indefinitely (and sorts last under EDF).
    pub patience_us: Option<f64>,
    /// Execution deadline from admission, mirroring the threaded
    /// `Fleet::submit_with_deadline` (patience bounds the *wait*, this
    /// bounds the *run*).
    pub deadline_us: Option<f64>,
    /// Service-time override, µs. `None` prices the session at its
    /// graph's solo makespan under this engine.
    pub service_us: Option<f64>,
}

impl Default for SimArrival {
    fn default() -> SimArrival {
        SimArrival {
            at_us: 0.0,
            bytes: 0,
            class: 1,
            patience_us: None,
            deadline_us: None,
            service_us: None,
        }
    }
}

/// Aging quantum of the simulated priority policy, mirroring
/// `SessionQueue`'s default (5ms per class step).
const SIM_AGE_QUANTUM_US: f64 = 5_000.0;

impl GraphiEngine {
    /// Open-loop serving mirror: replay a virtual-time **arrival trace**
    /// through §5.1 budget admission under a pluggable
    /// [`AdmissionPolicy`](crate::runtime::fleet::AdmissionPolicy) — the
    /// simulator twin of the threaded serving frontier (`runtime/serve.rs`
    /// + `SessionQueue`), so overload outcome classes stay differentially
    /// testable without real threads (`tests/serve_sessions.rs`).
    ///
    /// Discrete-event model, deliberately simple where the threads are
    /// rich: admission replays the queue's exact rules — head-of-line
    /// blocking per policy (FIFO ticket order / aged priority classes /
    /// EDF over `at_us + patience_us`), the oversized-runs-alone budget
    /// rule, patience expiry shedding ([`SimSessionOutcome::Shed`]) — but
    /// **admitted sessions run at solo speed** (their makespan alone on
    /// the fleet, or the `service_us` override), ignoring co-running
    /// contention. That keeps the mirror analytic; the contention story
    /// lives in [`run_concurrent`](Self::run_concurrent).
    ///
    /// A session whose service time outlives its `deadline_us` ends
    /// [`SimSessionOutcome::DeadlineExceeded`] with the lazy-discard
    /// truncation of [`run_concurrent_faulty`](Self::run_concurrent_faulty).
    /// Returned records and `makespan_us` (quiescence) are on the
    /// absolute virtual timeline; budget bytes are held from grant to
    /// quiescence, exactly like an [`crate::runtime::fleet::AdmissionPermit`].
    pub fn run_open_loop(
        &self,
        graphs: &[&Graph],
        env: &SimEnv,
        arrivals: &[SimArrival],
        budget_bytes: u64,
        policy: crate::runtime::fleet::AdmissionPolicy,
    ) -> Vec<SessionSimResult> {
        // a batch cap of 1 makes every request a zero-window singleton
        // entry: the batched path degenerates to exactly the original
        // per-arrival admission loop (same per-index pricing seeds)
        self.run_open_loop_batched(graphs, env, arrivals, budget_bytes, policy, 0.0, 1)
    }

    /// [`run_open_loop`](Self::run_open_loop) with **cross-session
    /// dynamic batching**, mirroring the threaded serving frontier's
    /// [`Batcher`](crate::runtime::serve::Batcher) rules so batching
    /// stays differentially testable (`tests/serve_sessions.rs`):
    ///
    /// * Arrivals referencing the **same `Graph`** (pointer identity —
    ///   the serve loop's zoo key) that land within `batch_window_us` of
    ///   a group's first member merge, up to `max_batch` per group.
    /// * A group **closes** at `leader.at_us + batch_window_us`, or the
    ///   instant it fills to `max_batch`; admission happens at close, so
    ///   a singleton group pays the full window in latency — exactly
    ///   like a threaded leader waiting out its window.
    /// * A batch is **one admission entry**: bytes are the member sum,
    ///   the class is the member min (most urgent), admission patience
    ///   and execution deadline are the member mins (measured from close
    ///   and grant respectively), and a shed or deadline terminal fans
    ///   out to every member.
    /// * Multi-member batches are priced as their
    ///   [`Graph::disjoint_union`] run on this engine (seeded by the
    ///   leader's arrival index); batches whose members all carry
    ///   `service_us` overrides take the override **max** (concurrent
    ///   components quiesce together at the slowest member).
    pub fn run_open_loop_batched(
        &self,
        graphs: &[&Graph],
        env: &SimEnv,
        arrivals: &[SimArrival],
        budget_bytes: u64,
        policy: crate::runtime::fleet::AdmissionPolicy,
        batch_window_us: f64,
        max_batch: usize,
    ) -> Vec<SessionSimResult> {
        use crate::runtime::fleet::AdmissionPolicy;
        assert!(!graphs.is_empty(), "run_open_loop needs at least one arrival");
        assert_eq!(graphs.len(), arrivals.len(), "one graph per arrival");
        assert!(
            arrivals.windows(2).all(|w| w[0].at_us <= w[1].at_us),
            "arrival traces must be in time order (arrival order is the ticket order)"
        );
        assert!(budget_bytes > 0, "a zero budget admits nothing");
        assert!(max_batch >= 1, "max_batch is a count (≥1)");
        assert!(
            batch_window_us.is_finite() && batch_window_us >= 0.0,
            "batch windows are finite and non-negative"
        );
        assert!(
            self.phase_plan.is_none() && self.duration_overrides.is_none() && self.width_plan.is_none(),
            "phase/width plans and duration overrides are per graph; price sessions individually"
        );

        // ---- batch formation: replay the Batcher's window/size rules on
        // the virtual timeline → (close time, member arrival indices) ----
        let mut entries: Vec<(f64, Vec<usize>)> = Vec::new();
        {
            let mut open: Vec<usize> = Vec::new(); // entry indices still accepting
            for (i, a) in arrivals.iter().enumerate() {
                let mut joined = false;
                if max_batch > 1 {
                    // a group stops accepting once its window has passed
                    // or it filled (filling fixed its close time below)
                    open.retain(|&ei| {
                        let leader = entries[ei].1[0];
                        entries[ei].1.len() < max_batch
                            && a.at_us <= arrivals[leader].at_us + batch_window_us
                    });
                    if let Some(&ei) = open
                        .iter()
                        .find(|&&ei| std::ptr::eq(graphs[entries[ei].1[0]], graphs[i]))
                    {
                        entries[ei].1.push(i);
                        if entries[ei].1.len() == max_batch {
                            // filling closes the group on the spot
                            entries[ei].0 = a.at_us;
                        }
                        joined = true;
                    }
                }
                if !joined {
                    let close = if max_batch > 1 { a.at_us + batch_window_us } else { a.at_us };
                    entries.push((close, vec![i]));
                    if max_batch > 1 {
                        open.push(entries.len() - 1);
                    }
                }
            }
        }
        // admission order is close order (the threaded leader enqueues at
        // close); ties break by leader arrival order
        entries.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1[0].cmp(&b.1[0])));

        // ---- per-entry admission parameters and pricing ----
        struct Priced {
            service_us: f64,
            /// union-id records for union-priced batches, local-id
            /// records for solo-priced singletons, `None` for overrides
            records: Option<Vec<OpRecord>>,
            bytes: u64,
            class: u8,
            patience_us: Option<f64>,
            deadline_us: Option<f64>,
        }
        let priced: Vec<Priced> = entries
            .iter()
            .map(|(_, members)| {
                let bytes = members.iter().map(|&m| arrivals[m].bytes).sum();
                let class = members.iter().map(|&m| arrivals[m].class).min().unwrap_or(1);
                let min_opt = |f: fn(&SimArrival) -> Option<f64>| {
                    members
                        .iter()
                        .filter_map(|&m| f(&arrivals[m]))
                        .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.min(v))))
                };
                let patience_us = min_opt(|a| a.patience_us);
                let deadline_us = min_opt(|a| a.deadline_us);
                let (service_us, records) = if members.len() == 1 {
                    // solo pricing, independent noise per arrival index
                    let m = members[0];
                    match arrivals[m].service_us {
                        Some(s) => (s, None),
                        None => {
                            let env_m = SimEnv {
                                cost: env.cost.clone(),
                                seed: env.seed ^ ((m as u64 + 1) << 32),
                            };
                            let r = self.run(graphs[m], &env_m);
                            (r.makespan_us, Some(r.records))
                        }
                    }
                } else if members.iter().all(|&m| arrivals[m].service_us.is_some()) {
                    // concurrent components quiesce at the slowest member
                    let s = members
                        .iter()
                        .map(|&m| arrivals[m].service_us.unwrap())
                        .fold(0.0f64, f64::max);
                    (s, None)
                } else {
                    let parts: Vec<&Graph> = members.iter().map(|&m| graphs[m]).collect();
                    let (union, _) = Graph::disjoint_union(&parts);
                    let env_b = SimEnv {
                        cost: env.cost.clone(),
                        seed: env.seed ^ ((members[0] as u64 + 1) << 32),
                    };
                    let r = self.run(&union, &env_b);
                    (r.makespan_us, Some(r.records))
                };
                Priced { service_us, records, bytes, class, patience_us, deadline_us }
            })
            .collect();

        #[derive(Clone, Copy)]
        enum Ev {
            // ranked: at equal times completions free budget first, then
            // expiries shed, then new arrivals queue
            Complete(usize),
            Expire(usize),
            Arrive(usize),
        }
        fn ev_key(t: f64, ev: Ev) -> (f64, u8, usize) {
            match ev {
                Ev::Complete(i) => (t, 0, i),
                Ev::Expire(i) => (t, 1, i),
                Ev::Arrive(i) => (t, 2, i),
            }
        }
        let mut events: Vec<(f64, Ev)> =
            entries.iter().enumerate().map(|(i, e)| (e.0, Ev::Arrive(i))).collect();
        let mut waiting: Vec<usize> = Vec::new();
        let mut in_use = 0u64;
        // the queue's exact budget rule: oversized sessions run alone
        let fits = |used: u64, bytes: u64| used == 0 || used.saturating_add(bytes) <= budget_bytes;
        let mut results: Vec<SessionSimResult> = arrivals
            .iter()
            .map(|_| SessionSimResult {
                records: Vec::new(),
                makespan_us: 0.0,
                outcome: SimSessionOutcome::Shed,
            })
            .collect();

        while !events.is_empty() {
            let mut best = 0;
            for k in 1..events.len() {
                let (ta, ea) = events[k];
                let (tb, eb) = events[best];
                let (ka, kb) = (ev_key(ta, ea), ev_key(tb, eb));
                if ka.0.total_cmp(&kb.0).then(ka.1.cmp(&kb.1)).then(ka.2.cmp(&kb.2)).is_lt() {
                    best = k;
                }
            }
            let (t, ev) = events.swap_remove(best);
            match ev {
                Ev::Arrive(i) => {
                    waiting.push(i);
                    if let Some(p) = priced[i].patience_us {
                        events.push((entries[i].0 + p, Ev::Expire(i)));
                    }
                }
                Ev::Expire(i) => {
                    // still in line at patience expiry ⇒ the whole batch
                    // sheds, one counted shed per member (granted entries
                    // are out of `waiting`, so this no-ops)
                    if let Some(pos) = waiting.iter().position(|&w| w == i) {
                        waiting.swap_remove(pos);
                        for &m in &entries[i].1 {
                            results[m] = SessionSimResult {
                                records: Vec::new(),
                                makespan_us: t,
                                outcome: SimSessionOutcome::Shed,
                            };
                        }
                    }
                }
                Ev::Complete(i) => in_use -= priced[i].bytes,
            }
            // grant loop: the head of line per policy admits while it
            // fits; a blocked head blocks everyone (the anti-starvation
            // discipline the threaded queue spec-tests)
            loop {
                let policy_key = |i: usize| -> f64 {
                    let close = entries[i].0;
                    match policy {
                        AdmissionPolicy::Fifo => i as f64,
                        AdmissionPolicy::Priority => {
                            let aged = ((t - close) / SIM_AGE_QUANTUM_US).floor();
                            (priced[i].class as f64 - aged).max(0.0)
                        }
                        AdmissionPolicy::Edf => {
                            priced[i].patience_us.map_or(f64::INFINITY, |p| close + p)
                        }
                    }
                };
                let head = waiting.iter().copied().min_by(|&x, &y| {
                    policy_key(x).total_cmp(&policy_key(y)).then(x.cmp(&y))
                });
                let Some(i) = head else { break };
                if !fits(in_use, priced[i].bytes) {
                    break;
                }
                waiting.retain(|&w| w != i);
                in_use += priced[i].bytes;
                let p = &priced[i];
                let (outcome, quiesce_rel, cut) = match p.deadline_us {
                    Some(d) if p.service_us > d => {
                        // lazy discard at the deadline cut, as in
                        // run_concurrent_faulty — quiescence is joint:
                        // every member's in-flight ops drain together
                        let q = p
                            .records
                            .as_ref()
                            .map(|rs| {
                                rs.iter()
                                    .filter(|r| r.start_us < d)
                                    .fold(d, |m, r| m.max(r.end_us))
                            })
                            .unwrap_or(d);
                        (SimSessionOutcome::DeadlineExceeded, q, d)
                    }
                    _ => (SimSessionOutcome::Completed, p.service_us, f64::INFINITY),
                };
                events.push((t + quiesce_rel, Ev::Complete(i)));
                let members = &entries[i].1;
                let glen = graphs[members[0]].len() as NodeId;
                for (pos, &m) in members.iter().enumerate() {
                    let records: Vec<OpRecord> = match &p.records {
                        None => Vec::new(),
                        Some(rs) if members.len() == 1 => rs
                            .iter()
                            .filter(|r| r.start_us < cut)
                            .map(|r| OpRecord {
                                node: r.node,
                                executor: r.executor,
                                start_us: r.start_us + t,
                                end_us: r.end_us + t,
                            })
                            .collect(),
                        // the member's contiguous component slice of the
                        // union, mapped back to model-local node ids
                        Some(rs) => rs
                            .iter()
                            .filter(|r| r.node / glen == pos as NodeId && r.start_us < cut)
                            .map(|r| OpRecord {
                                node: r.node % glen,
                                executor: r.executor,
                                start_us: r.start_us + t,
                                end_us: r.end_us + t,
                            })
                            .collect(),
                    };
                    results[m] = SessionSimResult {
                        records,
                        // every member resolves when the batch quiesces,
                        // exactly like a threaded member's handle.wait()
                        makespan_us: t + quiesce_rel,
                        outcome,
                    };
                }
            }
        }
        results
    }
}

impl Engine for GraphiEngine {
    fn name(&self) -> String {
        format!(
            "graphi-{}x{}-{}{}{}{}",
            self.executors,
            self.threads_per,
            self.policy.name(),
            match self.placement {
                PlacementKind::PinnedDisjoint => "",
                PlacementKind::PinnedSharedTiles => "-sharedL2",
                PlacementKind::OsManaged => "-unpinned",
            },
            if self.phase_plan.is_some() {
                "-phased"
            } else {
                match self.dispatch {
                    DispatchMode::Centralized => "",
                    DispatchMode::Decentralized => "-decentral",
                }
            },
            match &self.width_plan {
                Some(p) if !p.is_uniform_one() => "-moldable",
                _ => "",
            }
        )
    }

    fn run(&self, graph: &Graph, env: &SimEnv) -> RunResult {
        let result = if let Some(plan) = &self.phase_plan {
            self.run_phased(graph, env, plan)
        } else {
            let sim = Sim::new(graph, env, self);
            match self.dispatch {
                DispatchMode::Centralized => sim.run(),
                DispatchMode::Decentralized => sim.run_decentralized(),
            }
        };
        debug_assert!(
            result.validate(graph).is_ok(),
            "graphi produced invalid schedule: {:?}",
            result.validate(graph)
        );
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::levels::makespan_lower_bound;
    use crate::models::mlp::{build as mlp, MlpConfig};
    use crate::models::{self, ModelKind, ModelSize};

    fn env() -> SimEnv {
        SimEnv::knl_deterministic()
    }

    #[test]
    fn mlp_schedule_is_valid() {
        let g = mlp(&MlpConfig::default());
        let r = GraphiEngine::new(4, 16).run(&g, &env());
        r.validate(&g).unwrap();
        assert!(r.makespan_us > 0.0);
        assert_eq!(r.records.len(), g.len());
    }

    #[test]
    fn makespan_respects_lower_bound() {
        let g = mlp(&MlpConfig::default());
        let e = env();
        let durations: Vec<f64> = g
            .nodes()
            .iter()
            .map(|n| e.cost.duration_us(&n.kind, 16))
            .collect();
        let bound = makespan_lower_bound(&g, &durations, 4);
        let r = GraphiEngine::new(4, 16).run(&g, &e);
        // tiny ops run faster than their cost-model duration on the LW
        // lane, so allow a small tolerance below the bound
        assert!(
            r.makespan_us > bound * 0.8,
            "makespan {} below bound {bound}",
            r.makespan_us
        );
    }

    #[test]
    fn lstm_parallel_beats_single_executor_fleet() {
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let e = env();
        let one = GraphiEngine::new(1, 64).run(&g, &e).makespan_us;
        let eight = GraphiEngine::new(8, 8).run(&g, &e).makespan_us;
        assert!(
            eight < one,
            "8×8 ({eight}) should beat 1×64 ({one}) on small LSTM"
        );
    }

    #[test]
    fn cp_first_no_worse_than_anti_critical() {
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let e = env();
        let cp = GraphiEngine::new(8, 8).run(&g, &e).makespan_us;
        let anti = GraphiEngine::new(8, 8)
            .with_policy(Policy::AntiCritical)
            .run(&g, &e)
            .makespan_us;
        assert!(cp <= anti * 1.02, "cp {cp} vs anti {anti}");
    }

    #[test]
    fn deterministic_given_seed() {
        let g = mlp(&MlpConfig::default());
        let e = SimEnv::knl(42);
        let a = GraphiEngine::new(4, 16).run(&g, &e).makespan_us;
        let b = GraphiEngine::new(4, 16).run(&g, &e).makespan_us;
        assert_eq!(a, b);
    }

    #[test]
    fn tiny_ops_use_lightweight_executor() {
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        let r = GraphiEngine::new(4, 16).run(&g, &env());
        assert!(r.metrics.lightweight_ops > 0, "scalar input ops must route to LW");
        assert!(r
            .records
            .iter()
            .any(|rec| rec.executor == LIGHTWEIGHT_EXECUTOR));
    }

    #[test]
    fn unpinned_placement_slower() {
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let e = SimEnv::knl(7);
        let pinned = GraphiEngine::new(8, 8).run(&g, &e).makespan_us;
        let unpinned = GraphiEngine {
            placement: PlacementKind::OsManaged,
            ..GraphiEngine::new(8, 8)
        }
        .run(&g, &e)
        .makespan_us;
        assert!(
            unpinned > pinned * 1.15,
            "unpinned {unpinned} vs pinned {pinned} — Fig 3 expects a clear gap"
        );
    }

    #[test]
    fn duration_overrides_steer_dispatch_order() {
        // three independent GEMMs, one executor: dispatch order must follow
        // the override levels, not the cost model's
        use crate::graph::op::OpKind;
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        for name in ["a", "b", "c"] {
            b.add(name, OpKind::MatMul { m: 32, k: 64, n: 64 });
        }
        let g = b.build().unwrap();
        let run_order = |overrides: Vec<f64>| {
            let r = GraphiEngine::new(1, 8)
                .with_profiled_durations(overrides)
                .run(&g, &SimEnv::knl_deterministic());
            let mut recs = r.records.clone();
            recs.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
            recs.into_iter().map(|rec| rec.node).collect::<Vec<_>>()
        };
        assert_eq!(run_order(vec![5.0, 1.0, 9.0]), vec![2, 0, 1]);
        assert_eq!(run_order(vec![9.0, 5.0, 1.0]), vec![0, 1, 2]);
    }

    #[test]
    fn duration_overrides_schedule_stays_valid() {
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        // adversarial: constant durations (structure-only levels)
        let r = GraphiEngine::new(8, 8)
            .with_profiled_durations(vec![1.0; g.len()])
            .run(&g, &env());
        r.validate(&g).unwrap();
        assert_eq!(r.records.len(), g.len());
    }

    #[test]
    #[should_panic(expected = "duration overrides must cover every node")]
    fn duration_overrides_length_checked() {
        let g = mlp(&MlpConfig::default());
        let _ = GraphiEngine::new(2, 8)
            .with_profiled_durations(vec![1.0])
            .run(&g, &env());
    }

    #[test]
    fn utilization_sane() {
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let r = GraphiEngine::new(8, 8).run(&g, &env());
        let u = r.metrics.utilization(r.makespan_us);
        assert!((0.05..=1.0).contains(&u), "utilization {u}");
    }

    #[test]
    fn decentralized_schedule_is_valid_and_complete() {
        for kind in [ModelKind::Lstm, ModelKind::PathNet, ModelKind::Mlp] {
            let g = models::build(kind, ModelSize::Small);
            let r = GraphiEngine::new(4, 8)
                .with_dispatch(DispatchMode::Decentralized)
                .run(&g, &env());
            r.validate(&g).unwrap();
            assert_eq!(r.records.len(), g.len());
            assert_eq!(r.metrics.dispatches, g.len() as u64);
            assert_eq!(r.metrics.lightweight_ops, 0, "no LW lane in decentralized mode");
            assert!(r.metrics.scheduler_busy_us > 0.0, "resolution work must be accounted");
        }
    }

    #[test]
    fn decentralized_deterministic_given_seed() {
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let e = SimEnv::knl(42);
        let engine = GraphiEngine::new(4, 8).with_dispatch(DispatchMode::Decentralized);
        assert_eq!(engine.run(&g, &e).makespan_us, engine.run(&g, &e).makespan_us);
    }

    /// 40 layers × 16 tiny element-wise ops (640 nodes): the small-op-heavy
    /// shape where dispatch throughput (not op work) is the bottleneck.
    fn wide_small_op_graph() -> crate::graph::Graph {
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let mut prev: Vec<crate::graph::NodeId> = Vec::new();
        for layer in 0..40 {
            let mut this = Vec::new();
            for i in 0..16 {
                let n = b.add(
                    format!("l{layer}n{i}"),
                    OpKind::Elementwise { n: 2_000, arity: 2, kind: EwKind::Arith },
                );
                if let Some(&p) = prev.get(i % prev.len().max(1)) {
                    b.depend(p, n);
                }
                this.push(n);
            }
            prev = this;
        }
        b.build().unwrap()
    }

    #[test]
    fn decentralized_beats_centralized_on_small_op_heavy_graph() {
        // the point of the tentpole: when per-op work is small, the
        // serialized scheduler round-trip dominates the centralized
        // makespan, while decentralized resolution spreads that cost
        // across executors. Structure-only levels + a wide graph of tiny
        // element-wise ops make dispatch throughput the bottleneck.
        let g = wide_small_op_graph();
        let e = SimEnv::knl_deterministic();
        let central = GraphiEngine::new(8, 8).run(&g, &e).makespan_us;
        let decentral = GraphiEngine::new(8, 8)
            .with_dispatch(DispatchMode::Decentralized)
            .run(&g, &e)
            .makespan_us;
        assert!(
            decentral < central,
            "decentralized ({decentral}) should beat centralized ({central}) on small ops"
        );
    }

    #[test]
    fn dispatch_mode_shows_in_engine_name() {
        let c = GraphiEngine::new(4, 8);
        let d = GraphiEngine::new(4, 8).with_dispatch(DispatchMode::Decentralized);
        assert!(!c.name().contains("decentral"));
        assert!(d.name().ends_with("-decentral"), "{}", d.name());
        let p = GraphiEngine::new(4, 8)
            .with_phase_plan(PhasePlan::uniform(2, DispatchMode::Centralized, 1));
        assert!(p.name().ends_with("-phased"), "{}", p.name());
        let m = GraphiEngine::new(4, 8).with_width_plan(WidthPlan::uniform(2));
        assert!(m.name().ends_with("-moldable"), "{}", m.name());
        let id = GraphiEngine::new(4, 8).with_width_plan(WidthPlan::uniform(1));
        assert!(!id.name().contains("moldable"), "identity plan is not moldable: {}", id.name());
    }

    /// Two independent chains of large GEMMs: parallelism 2 on an
    /// 8-executor fleet leaves six peers idle — the shape where molding
    /// each GEMM onto a gang pays.
    fn wide_gemm_graph() -> crate::graph::Graph {
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        for chain in 0..2 {
            let mut prev = None;
            for i in 0..6 {
                let n = b.add(
                    format!("c{chain}g{i}"),
                    OpKind::MatMul { m: 512, k: 1024, n: 1024 },
                );
                if let Some(p) = prev {
                    b.depend(p, n);
                }
                prev = Some(n);
            }
        }
        b.build().unwrap()
    }

    #[test]
    fn width_one_plan_is_byte_identical_to_no_plan() {
        // acceptance: `w = 1` everywhere must be bit-compatible with
        // today's behavior — same records, same makespan, and (because the
        // env is noisy) the same RNG draw order
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let e = SimEnv::knl(42);
        for mode in DispatchMode::ALL {
            let base = GraphiEngine::new(8, 8).with_dispatch(mode).run(&g, &e);
            let planned = GraphiEngine::new(8, 8)
                .with_dispatch(mode)
                .with_width_plan(WidthPlan::uniform(1))
                .run(&g, &e);
            assert_eq!(base.makespan_us, planned.makespan_us, "{mode:?}");
            assert_eq!(base.records, planned.records, "{mode:?}");
            assert_eq!(planned.metrics.gangs_formed, 0);
            assert_eq!(planned.metrics.gang_recruits, 0);
        }
    }

    #[test]
    fn moldable_runs_are_valid_and_form_gangs_in_both_modes() {
        use crate::graph::op::OpClass;
        let g = wide_gemm_graph();
        let mut plan = WidthPlan::uniform(1);
        plan.set(OpClass::Gemm, 4);
        for mode in DispatchMode::ALL {
            let r = GraphiEngine::new(8, 2)
                .with_dispatch(mode)
                .with_width_plan(plan.clone())
                .run(&g, &env());
            r.validate(&g).unwrap();
            assert_eq!(r.records.len(), g.len());
            assert!(r.metrics.gangs_formed > 0, "{mode:?} formed no gangs");
            assert!(r.metrics.gang_recruits >= r.metrics.gangs_formed);
        }
    }

    #[test]
    fn wide_gemms_gain_from_width_while_small_ops_prefer_width_one() {
        // the tentpole's differential: the same width knob that speeds up
        // narrow chains of wide GEMMs slows down the 640-node small-op
        // graph (oversaturated curves + lost inter-op parallelism + paid
        // recruit handshakes), so the autotuner must find opposite winners
        use crate::graph::op::OpClass;
        let e = env();
        let mut gemm4 = WidthPlan::uniform(1);
        gemm4.set(OpClass::Gemm, 4);
        let g = wide_gemm_graph();
        let solo = GraphiEngine::new(8, 2).run(&g, &e).makespan_us;
        let molded =
            GraphiEngine::new(8, 2).with_width_plan(gemm4).run(&g, &e).makespan_us;
        assert!(
            molded < solo * 0.8,
            "narrow wide-GEMM chains should gain from gangs: {molded} vs {solo}"
        );

        let small = wide_small_op_graph();
        let mut ew4 = WidthPlan::uniform(1);
        ew4.set(OpClass::Elementwise, 4);
        let solo = GraphiEngine::new(8, 2).run(&small, &e).makespan_us;
        let molded =
            GraphiEngine::new(8, 2).with_width_plan(ew4).run(&small, &e).makespan_us;
        assert!(
            molded > solo,
            "the 640-node small-op graph should prefer w = 1: {molded} vs {solo}"
        );
    }

    /// A 2-domain KNL variant (SNC-2-like): domains of 34 cores.
    fn two_domain_env() -> SimEnv {
        let mut env = SimEnv::knl_deterministic();
        env.cost.machine = crate::cost::machine::Machine {
            numa_domains: 2,
            ..crate::cost::machine::Machine::knl7250()
        };
        env
    }

    #[test]
    fn same_domain_steals_dominate_on_a_two_domain_fleet() {
        // acceptance: on a 2-domain fleet running the small-op-heavy
        // 640-node graph, NUMA-aware victim ranking keeps at least as many
        // steals inside the domain as across it (level ties stay local;
        // only a strictly deeper remote critical path crosses the mesh)
        let g = wide_small_op_graph();
        let r = GraphiEngine::new(8, 8)
            .with_dispatch(DispatchMode::Decentralized)
            .run(&g, &two_domain_env());
        r.validate(&g).unwrap();
        assert!(r.metrics.steals > 0, "a 16-wide graph on 8 executors must steal");
        let local = r.metrics.steals - r.metrics.steals_cross_domain;
        assert!(
            local >= r.metrics.steals_cross_domain,
            "same-domain steals ({local}) must be ≥ cross-domain ({})",
            r.metrics.steals_cross_domain
        );
    }

    #[test]
    fn quadrant_mode_never_pays_cross_domain_steals() {
        let g = wide_small_op_graph();
        let r = GraphiEngine::new(8, 8)
            .with_dispatch(DispatchMode::Decentralized)
            .run(&g, &SimEnv::knl_deterministic());
        assert!(r.metrics.steals > 0);
        assert_eq!(r.metrics.steals_cross_domain, 0, "one domain ⇒ nothing crosses");
    }

    #[test]
    fn cross_domain_surcharge_is_priced_into_the_makespan() {
        // same fleet and graph; the 2-domain run pays the mesh surcharge
        // on its (few) cross-domain steals plus the SNC span penalty, so
        // it cannot be faster than pricing with the surcharge zeroed
        let g = wide_small_op_graph();
        let mut cheap = two_domain_env();
        cheap.cost.cal.steal_cross_domain_us = 0.0;
        let engine = GraphiEngine::new(8, 8).with_dispatch(DispatchMode::Decentralized);
        let priced = engine.run(&g, &two_domain_env());
        let free = engine.run(&g, &cheap);
        assert!(
            priced.makespan_us >= free.makespan_us,
            "surcharge must not speed anything up: {} vs {}",
            priced.makespan_us,
            free.makespan_us
        );
    }

    #[test]
    fn phased_run_is_valid_and_switches_at_boundaries() {
        use crate::graph::width_phases;
        let g = wide_small_op_graph();
        let e = SimEnv::knl_deterministic();
        let phases = width_phases(&g, 2);
        // 640-node layered graph: every depth is 16 wide ⇒ one wide phase
        assert_eq!(phases.len(), 1);
        // force structure with the LSTM model instead (chains + bands)
        let lstm = models::build(ModelKind::Lstm, ModelSize::Small);
        let lphases = width_phases(&lstm, 4);
        let alternating: Vec<DispatchMode> = (0..lphases.len())
            .map(|i| if i % 2 == 0 { DispatchMode::Centralized } else { DispatchMode::Decentralized })
            .collect();
        let plan = PhasePlan { threshold: 4, modes: alternating };
        let expected_switches = plan.mode_switches();
        let r = GraphiEngine::new(4, 8).with_phase_plan(plan).run(&lstm, &e);
        r.validate(&lstm).unwrap();
        assert_eq!(r.records.len(), lstm.len());
        assert_eq!(r.metrics.dispatches + r.metrics.lightweight_ops, lstm.len() as u64);
        assert_eq!(r.metrics.mode_switches, expected_switches);
        if lphases.len() > 1 {
            assert!(expected_switches > 0, "alternating plan over >1 phase must switch");
        }
    }

    #[test]
    fn single_phase_plan_matches_uniform_run_semantics() {
        // a one-phase plan is the uniform engine with an extra label: same
        // schedule validity, same op count, and (deterministic env) the
        // same makespan as the equivalent uniform run with the same seed
        // derivation is not guaranteed — only semantics are
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let e = SimEnv::knl_deterministic();
        let phases = crate::graph::width_phases(&g, 1);
        assert_eq!(phases.len(), 1, "threshold 1 makes every depth wide");
        for mode in DispatchMode::ALL {
            let r = GraphiEngine::new(4, 8)
                .with_phase_plan(PhasePlan::uniform(1, mode, 1))
                .run(&g, &e);
            r.validate(&g).unwrap();
            assert_eq!(r.records.len(), g.len());
            assert_eq!(r.metrics.mode_switches, 0);
        }
    }

    #[test]
    #[should_panic(expected = "does not line up")]
    fn mismatched_phase_plan_panics() {
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let plan = PhasePlan { threshold: 2, modes: vec![DispatchMode::Centralized; 99] };
        let _ = GraphiEngine::new(4, 8).with_phase_plan(plan).run(&g, &SimEnv::knl_deterministic());
    }

    #[test]
    fn run_concurrent_executes_every_session_exactly_once_in_both_modes() {
        let a = models::build(ModelKind::Mlp, ModelSize::Small);
        let b = models::build_inference(ModelKind::PathNet, ModelSize::Small);
        let e = env();
        for mode in DispatchMode::ALL {
            let engine = GraphiEngine::new(4, 8).with_dispatch(mode);
            let (union_result, sessions) = engine.run_concurrent(&[&a, &b], &e);
            assert_eq!(sessions.len(), 2, "{}", mode.name());
            assert_eq!(
                union_result.records.len(),
                a.len() + b.len(),
                "{}",
                mode.name()
            );
            for (graph, session) in [(&a, &sessions[0]), (&b, &sessions[1])] {
                // per-session exactly-once + dependency-valid order
                assert_eq!(session.records.len(), graph.len(), "{}", mode.name());
                let mut recs = session.records.clone();
                recs.sort_by(|x, y| x.start_us.total_cmp(&y.start_us));
                let order: Vec<crate::graph::NodeId> = recs.iter().map(|r| r.node).collect();
                graph.validate_order(&order).unwrap();
                assert!(session.makespan_us > 0.0);
                assert!(session.makespan_us <= union_result.makespan_us);
            }
        }
    }

    #[test]
    fn run_concurrent_interleaves_sessions_on_the_shared_fleet() {
        // two equal-shape graphs admitted together must overlap in virtual
        // time — the fleet is shared, not serialized per session
        let a = models::build(ModelKind::Mlp, ModelSize::Small);
        let b = models::build(ModelKind::Mlp, ModelSize::Small);
        let e = env();
        let (_, sessions) =
            GraphiEngine::new(4, 8).run_concurrent(&[&a, &b], &e);
        let first_start = |s: &SessionSimResult| {
            s.records.iter().map(|r| r.start_us).fold(f64::INFINITY, f64::min)
        };
        // both sessions start before either finishes ⇒ concurrent
        assert!(first_start(&sessions[0]) < sessions[1].makespan_us);
        assert!(first_start(&sessions[1]) < sessions[0].makespan_us);
    }

    #[test]
    #[should_panic(expected = "at least one graph")]
    fn run_concurrent_rejects_empty_session_list() {
        let _ = GraphiEngine::new(4, 8).run_concurrent(&[], &env());
    }

    #[test]
    fn faulty_sim_sessions_truncate_while_healthy_peers_complete() {
        let a = models::build(ModelKind::Mlp, ModelSize::Small);
        let b = models::build(ModelKind::Mlp, ModelSize::Small);
        let e = env();
        for mode in DispatchMode::ALL {
            let engine = GraphiEngine::new(4, 8).with_dispatch(mode);
            // session 0 panics mid-graph; session 1 is healthy
            let panic_node = (a.len() / 2) as NodeId;
            let faults = [SimFault { panic_at: Some(panic_node), ..SimFault::default() }, SimFault::default()];
            let (_, sessions) = engine.run_concurrent_faulty(&[&a, &b], &e, &faults);
            let failed = &sessions[0];
            assert_eq!(failed.outcome, SimSessionOutcome::Failed { node: panic_node }, "{}", mode.name());
            assert!(failed.records.len() < a.len(), "{}", mode.name());
            assert!(
                failed.records.iter().all(|r| r.node != panic_node),
                "{}: the panicked op must not appear in the trace",
                mode.name()
            );
            // truncation preserves dependency validity of what did run
            let mut recs = failed.records.clone();
            recs.sort_by(|x, y| x.start_us.total_cmp(&y.start_us));
            let executed: Vec<NodeId> = recs.iter().map(|r| r.node).collect();
            a.validate_order_prefix(&executed).unwrap_or_else(|err| {
                panic!("{}: truncated trace violates deps: {err}", mode.name())
            });
            // the healthy session is untouched by its peer's fault
            let healthy = &sessions[1];
            assert_eq!(healthy.outcome, SimSessionOutcome::Completed, "{}", mode.name());
            assert_eq!(healthy.records.len(), b.len(), "{}", mode.name());
        }
    }

    #[test]
    fn sim_deadline_and_cancel_classify_by_earliest_event() {
        let a = models::build(ModelKind::Mlp, ModelSize::Small);
        let e = env();
        let (_, full) = GraphiEngine::new(4, 8).run_concurrent(&[&a], &e);
        let half = full[0].makespan_us / 2.0;
        // deadline at half the healthy makespan ⇒ DeadlineExceeded
        let (_, s) = GraphiEngine::new(4, 8).run_concurrent_faulty(
            &[&a],
            &e,
            &[SimFault { deadline_us: Some(half), ..SimFault::default() }],
        );
        assert_eq!(s[0].outcome, SimSessionOutcome::DeadlineExceeded);
        assert!(s[0].records.len() < a.len());
        // an earlier cancel beats the deadline
        let (_, s) = GraphiEngine::new(4, 8).run_concurrent_faulty(
            &[&a],
            &e,
            &[SimFault { cancel_at_us: Some(half / 2.0), deadline_us: Some(half), ..SimFault::default() }],
        );
        assert_eq!(s[0].outcome, SimSessionOutcome::Cancelled);
        // a deadline past the healthy makespan never fires
        let (_, s) = GraphiEngine::new(4, 8).run_concurrent_faulty(
            &[&a],
            &e,
            &[SimFault { deadline_us: Some(full[0].makespan_us * 2.0), ..SimFault::default() }],
        );
        assert_eq!(s[0].outcome, SimSessionOutcome::Completed);
        assert_eq!(s[0].records.len(), a.len());
    }

    #[test]
    fn open_loop_with_ample_budget_admits_everything_on_arrival() {
        use crate::runtime::fleet::AdmissionPolicy;
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let arrivals: Vec<SimArrival> = (0..3)
            .map(|i| SimArrival { at_us: i as f64 * 1e5, bytes: 1, ..SimArrival::default() })
            .collect();
        let s = GraphiEngine::new(4, 8).run_open_loop(
            &[&g, &g, &g],
            &env(),
            &arrivals,
            1 << 30,
            AdmissionPolicy::Fifo,
        );
        for (i, r) in s.iter().enumerate() {
            assert_eq!(r.outcome, SimSessionOutcome::Completed, "session {i}");
            assert_eq!(r.records.len(), g.len(), "session {i}");
            // admitted at arrival, runs at solo speed from there
            assert!(r.makespan_us > arrivals[i].at_us, "session {i}");
            assert!(r.records.iter().all(|rec| rec.start_us >= arrivals[i].at_us), "session {i}");
        }
    }

    #[test]
    fn open_loop_sheds_the_impatient_and_serves_the_patient() {
        use crate::runtime::fleet::AdmissionPolicy;
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        // a budget-holding head (service 1000µs), one impatient waiter
        // (patience 100µs ⇒ shed at t=150), one patient waiter (granted
        // at the holder's completion)
        let arrivals = [
            SimArrival { at_us: 0.0, bytes: 100, service_us: Some(1000.0), ..SimArrival::default() },
            SimArrival {
                at_us: 50.0,
                bytes: 10,
                patience_us: Some(100.0),
                service_us: Some(10.0),
                ..SimArrival::default()
            },
            SimArrival { at_us: 60.0, bytes: 10, service_us: Some(10.0), ..SimArrival::default() },
        ];
        let s = GraphiEngine::new(4, 8).run_open_loop(
            &[&g, &g, &g],
            &env(),
            &arrivals,
            100,
            AdmissionPolicy::Fifo,
        );
        assert_eq!(s[0].outcome, SimSessionOutcome::Completed);
        assert_eq!(s[0].makespan_us, 1000.0);
        assert_eq!(s[1].outcome, SimSessionOutcome::Shed);
        assert_eq!(s[1].makespan_us, 150.0, "shed exactly at patience expiry");
        assert_eq!(s[2].outcome, SimSessionOutcome::Completed);
        assert_eq!(s[2].makespan_us, 1010.0, "granted when the holder quiesced");
    }

    #[test]
    fn open_loop_policies_reorder_the_same_backlog() {
        use crate::runtime::fleet::AdmissionPolicy;
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        // a holder pins the budget while three waiters with opposing
        // FIFO / priority / EDF orders pile up behind it; service times
        // are distinct so the grant order is readable off makespans
        let arrivals = [
            SimArrival { at_us: 0.0, bytes: 100, service_us: Some(1000.0), ..SimArrival::default() },
            // FIFO first; lowest priority urgency; loosest EDF deadline
            SimArrival {
                at_us: 10.0,
                bytes: 100,
                class: 2,
                patience_us: Some(1e6),
                service_us: Some(10.0),
                ..SimArrival::default()
            },
            // middle everywhere
            SimArrival {
                at_us: 20.0,
                bytes: 100,
                class: 1,
                patience_us: Some(8e5),
                service_us: Some(10.0),
                ..SimArrival::default()
            },
            // FIFO last; most urgent class; tightest EDF deadline
            SimArrival {
                at_us: 30.0,
                bytes: 100,
                class: 0,
                patience_us: Some(6e5),
                service_us: Some(10.0),
                ..SimArrival::default()
            },
        ];
        let graphs = [&g, &g, &g, &g];
        let order_of = |policy: AdmissionPolicy| -> Vec<usize> {
            let s =
                GraphiEngine::new(4, 8).run_open_loop(&graphs, &env(), &arrivals, 100, policy);
            assert!(s.iter().all(|r| r.outcome == SimSessionOutcome::Completed), "{policy:?}");
            let mut idx: Vec<usize> = (1..4).collect();
            idx.sort_by(|&x, &y| s[x].makespan_us.total_cmp(&s[y].makespan_us));
            idx
        };
        assert_eq!(order_of(AdmissionPolicy::Fifo), vec![1, 2, 3]);
        // waits are ≪ the 5ms aging quantum, so raw classes order grants
        assert_eq!(order_of(AdmissionPolicy::Priority), vec![3, 2, 1]);
        assert_eq!(order_of(AdmissionPolicy::Edf), vec![3, 2, 1]);
    }

    #[test]
    fn open_loop_deadline_cuts_a_session_whose_service_outlives_it() {
        use crate::runtime::fleet::AdmissionPolicy;
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let e = env();
        let solo = GraphiEngine::new(4, 8).run(&g, &SimEnv {
            cost: e.cost.clone(),
            seed: e.seed ^ (1 << 32),
        });
        let half = solo.makespan_us / 2.0;
        let arrivals =
            [SimArrival { at_us: 0.0, bytes: 1, deadline_us: Some(half), ..SimArrival::default() }];
        let s = GraphiEngine::new(4, 8).run_open_loop(
            &[&g],
            &e,
            &arrivals,
            1 << 30,
            AdmissionPolicy::Fifo,
        );
        assert_eq!(s[0].outcome, SimSessionOutcome::DeadlineExceeded);
        assert!(s[0].records.len() < g.len(), "lazy discard drops post-cut ops");
        assert!(s[0].makespan_us >= half, "quiescence joins the in-flight drain");
    }

    #[test]
    fn batched_open_loop_with_singleton_cap_matches_the_unbatched_path() {
        use crate::runtime::fleet::AdmissionPolicy;
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        // a contended backlog with classes, patience, and a deadline so
        // every policy key and every terminal path is exercised
        let arrivals = [
            SimArrival { at_us: 0.0, bytes: 100, service_us: Some(1000.0), ..SimArrival::default() },
            SimArrival {
                at_us: 10.0,
                bytes: 100,
                class: 2,
                patience_us: Some(1e6),
                ..SimArrival::default()
            },
            SimArrival {
                at_us: 20.0,
                bytes: 100,
                class: 0,
                patience_us: Some(100.0),
                ..SimArrival::default()
            },
            SimArrival {
                at_us: 30.0,
                bytes: 100,
                class: 1,
                deadline_us: Some(1.0),
                ..SimArrival::default()
            },
        ];
        let graphs = [&g, &g, &g, &g];
        let e = env();
        for policy in [AdmissionPolicy::Fifo, AdmissionPolicy::Priority, AdmissionPolicy::Edf] {
            let plain = GraphiEngine::new(4, 8).run_open_loop(&graphs, &e, &arrivals, 100, policy);
            // max_batch == 1 must ignore the window entirely: every
            // arrival is a zero-delay singleton with its solo pricing seed
            let batched = GraphiEngine::new(4, 8)
                .run_open_loop_batched(&graphs, &e, &arrivals, 100, policy, 777.0, 1);
            assert_eq!(plain.len(), batched.len(), "{policy:?}");
            for (i, (p, b)) in plain.iter().zip(&batched).enumerate() {
                assert_eq!(p.outcome, b.outcome, "{policy:?} session {i}");
                assert_eq!(p.makespan_us, b.makespan_us, "{policy:?} session {i}");
                assert_eq!(p.records, b.records, "{policy:?} session {i}");
            }
        }
    }

    #[test]
    fn batch_formation_follows_the_window_size_and_compatibility_rules() {
        use crate::runtime::fleet::AdmissionPolicy;
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let h = models::build(ModelKind::Mlp, ModelSize::Medium);
        // three g-arrivals inside one 100µs window fill a cap-3 group at
        // t=20 (fill closes early); the h-arrival is incompatible and
        // waits out its own window; the straggler at t=5000 opens a fresh
        // singleton group and pays the full window before admission
        let arrivals = [
            SimArrival { at_us: 0.0, bytes: 1, service_us: Some(100.0), ..SimArrival::default() },
            SimArrival { at_us: 10.0, bytes: 1, service_us: Some(300.0), ..SimArrival::default() },
            SimArrival { at_us: 15.0, bytes: 1, service_us: Some(40.0), ..SimArrival::default() },
            SimArrival { at_us: 20.0, bytes: 1, service_us: Some(50.0), ..SimArrival::default() },
            SimArrival { at_us: 5000.0, bytes: 1, service_us: Some(70.0), ..SimArrival::default() },
        ];
        let graphs = [&g, &g, &h, &g, &g];
        let s = GraphiEngine::new(4, 8).run_open_loop_batched(
            &graphs,
            &env(),
            &arrivals,
            1 << 30,
            AdmissionPolicy::Fifo,
            100.0,
            3,
        );
        assert!(s.iter().all(|r| r.outcome == SimSessionOutcome::Completed));
        // batch members resolve together at the slowest override: the
        // group closed at t=20 and quiesces 300µs later
        for i in [0, 1, 3] {
            assert_eq!(s[i].makespan_us, 320.0, "member {i} of the filled group");
        }
        // the incompatible model closed at 15 + 100 and ran alone
        assert_eq!(s[2].makespan_us, 155.0);
        // the straggler closed at 5000 + 100: singleton leaders pay the
        // window, exactly like a threaded leader whose window expires
        assert_eq!(s[4].makespan_us, 5170.0);
    }

    #[test]
    fn batching_moves_the_knee_under_small_session_overload() {
        use crate::runtime::fleet::AdmissionPolicy;
        // the deterministic core of the serve-mode claim: a serial budget
        // (bytes == budget, so sessions run one at a time), arrivals 10×
        // faster than service, and 2ms patience. Unbatched, the line
        // grows by 900µs per grant and almost everything sheds; with an
        // 8-way batch each 1000µs service quantum retires 8 requests and
        // the same trace completes in full.
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let arrivals: Vec<SimArrival> = (0..40)
            .map(|i| SimArrival {
                at_us: i as f64 * 100.0,
                bytes: 100,
                service_us: Some(1000.0),
                patience_us: Some(2000.0),
                ..SimArrival::default()
            })
            .collect();
        let graphs: Vec<&Graph> = vec![&g; arrivals.len()];
        let e = env();
        let done = |s: &[SessionSimResult]| {
            s.iter().filter(|r| r.outcome == SimSessionOutcome::Completed).count()
        };
        let plain =
            GraphiEngine::new(4, 8).run_open_loop(&graphs, &e, &arrivals, 100, AdmissionPolicy::Fifo);
        let batched = GraphiEngine::new(4, 8).run_open_loop_batched(
            &graphs,
            &e,
            &arrivals,
            100,
            AdmissionPolicy::Fifo,
            1000.0,
            8,
        );
        assert!(done(&plain) <= 10, "unbatched overload must shed most of the trace");
        assert_eq!(done(&batched), arrivals.len(), "8-way batching clears the same trace");
        // conservation on the unbatched side: everything not completed
        // was shed while waiting (no deadlines in this trace)
        assert!(plain
            .iter()
            .all(|r| matches!(r.outcome, SimSessionOutcome::Completed | SimSessionOutcome::Shed)));
    }
}
