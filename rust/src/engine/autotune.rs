//! Adaptive parallel-setting autotuner (§4.2, grown up).
//!
//! The flat [`super::Profiler`] sweep runs every `(executors × threads)`
//! candidate for the same fixed iteration count — cheap configurations and
//! hopeless ones get identical budgets. This module replaces it with
//! **successive halving** over the same candidate space
//! ([`crate::sim::topology::candidate_configs`]):
//!
//! 1. run every candidate for one iteration;
//! 2. keep the best half (by cumulative mean makespan), double the
//!    per-candidate iteration budget;
//! 3. repeat until one candidate survives.
//!
//! Measurements accumulate across rounds (a survivor's round-2 mean folds
//! in its round-1 sample), so later rounds *refine* earlier ones instead of
//! discarding them. The search spends `Σ nᵣ·iᵣ` iterations, strictly fewer
//! than the `n · i_final` an exhaustive sweep needs at the same final
//! fidelity — on the 9-shape space restricted to one dispatch mode it is
//! 25 iterations versus 36 (or 27 for the legacy 3-iteration flat sweep).
//!
//! Since PR 3 the candidate space is two-dimensional: every fleet shape is
//! measured under both [`DispatchMode`]s (§4/§5 centralized vs
//! executor-side resolution + work stealing), so the search also decides
//! the dispatch architecture per workload — 18 candidates, 68 iterations
//! versus 144 exhaustive at the same final fidelity.
//!
//! After the winner is found, per-op durations are re-estimated at the
//! winning team size (the §4.2 duration-estimation job) so the caller can
//! feed them back into [`GraphiEngine`]'s critical-path levels via
//! `duration_overrides`, and persist everything as a versioned tuning
//! artifact ([`crate::runtime::artifacts::TuningArtifact`]) that later
//! runs load instead of re-searching.

use crate::graph::op::OpClass;
use crate::graph::{width_phases, Graph};
use crate::sim::topology::candidate_configs;
use crate::util::stats::Welford;

use super::profiler::{ConfigMeasurement, Profiler};
use super::ready::MAX_WIDTH;
use super::{DispatchMode, Engine, GraphiEngine, PhasePlan, SimEnv, WidthPlan};

/// Successive-halving search configuration.
#[derive(Debug, Clone)]
pub struct Autotuner {
    /// Worker cores to split among executors (machine cores − 2 reserved).
    pub worker_cores: usize,
    /// Extra model-specific configurations to seed into round 0.
    pub extra_configs: Vec<(usize, usize)>,
    /// Dispatch architectures to search as a candidate axis (PR 3): every
    /// `(executors, threads)` config is measured under each mode, so the
    /// search decides centralized-vs-decentralized per workload instead of
    /// hardcoding it. Restrict to one mode to reproduce the PR-2 search.
    pub dispatch_modes: Vec<DispatchMode>,
    /// Search the **per-phase** dispatch axis after the uniform winner is
    /// found (PR 4): split the graph into width phases at the winning
    /// executor count and greedily flip each phase's mode, adopting the
    /// plan only when its measured makespan beats the uniform winner's
    /// (Liu et al., arXiv:1810.08955: the right concurrency setting
    /// varies within one graph's phases). Only runs when both dispatch
    /// modes are in the candidate space — a single-axis search was
    /// explicitly restricted by the caller.
    pub phase_search: bool,
    /// Search **per-op-class gang widths** after the uniform winner is
    /// found (the moldable-ops axis): greedily raise one class's width at
    /// a time through the powers of two, adopting a plan only when its
    /// measured makespan beats the width-1 baseline at the same eval
    /// seed. Off by default — widths only pay on graphs whose per-op work
    /// scales past one executor's team (wide GEMMs), and every evaluation
    /// is a full simulated run.
    pub width_search: bool,
    /// Per-candidate iterations in round 0 (doubles every round).
    pub initial_iterations: usize,
    /// Cap on the per-candidate iterations of any single round.
    pub max_iterations: usize,
    /// Iterations of the post-search duration-estimation pass at the
    /// winning team size (the same pass the flat profiler runs).
    pub duration_iterations: usize,
}

impl Default for Autotuner {
    fn default() -> Self {
        Autotuner {
            worker_cores: 64,
            extra_configs: Vec::new(),
            dispatch_modes: DispatchMode::ALL.to_vec(),
            phase_search: true,
            width_search: false,
            initial_iterations: 1,
            max_iterations: 8,
            duration_iterations: 3,
        }
    }
}

/// One halving round's outcome.
#[derive(Debug, Clone)]
pub struct AutotuneRound {
    /// Per-candidate iterations *added* in this round.
    pub iterations: usize,
    /// Cumulative measurements of every candidate alive this round,
    /// best (lowest mean makespan) first.
    pub measurements: Vec<ConfigMeasurement>,
    /// Candidates that survived into the next round.
    pub survivors: Vec<((usize, usize), DispatchMode)>,
}

/// The search result.
#[derive(Debug, Clone)]
pub struct AutotuneReport {
    /// Winning `(executors, threads_per)` configuration.
    pub best: (usize, usize),
    /// Winning dispatch architecture.
    pub best_dispatch: DispatchMode,
    /// Cumulative mean makespan of the winner across all its iterations.
    pub best_makespan_us: f64,
    /// Per-op duration estimates at the winning team size, µs — feed these
    /// into [`GraphiEngine::with_profiled_durations`] (or persist them).
    pub durations_us: Vec<f64>,
    /// Round-by-round search trace.
    pub rounds: Vec<AutotuneRound>,
    /// Total profiling iterations the config search spent (excludes the
    /// duration-estimation pass, which the flat sweep pays identically,
    /// and the per-phase refinement, accounted in
    /// `phase_refine_iterations`).
    pub total_profile_iterations: usize,
    /// Per-candidate iterations of the last executed round.
    pub final_round_iterations: usize,
    /// Size of the initial candidate space.
    pub num_candidates: usize,
    /// Per-phase dispatch plan, `Some` only when the greedy flip search
    /// found a plan whose measured makespan beats the uniform winner's
    /// (and it actually mixes modes).
    pub phase_plan: Option<PhasePlan>,
    /// Makespan of the adopted phase plan (paired with `phase_plan`).
    pub phase_makespan_us: Option<f64>,
    /// Simulator runs the per-phase refinement spent (0 when skipped).
    pub phase_refine_iterations: usize,
    /// Per-op-class gang-width plan, `Some` only when the width search
    /// ([`Autotuner::width_search`]) found a non-uniform plan whose
    /// measured makespan beats the width-1 baseline.
    pub width_plan: Option<WidthPlan>,
    /// Makespan of the adopted width plan (paired with `width_plan`).
    pub width_makespan_us: Option<f64>,
    /// Simulator runs the width refinement spent (0 when skipped).
    pub width_refine_iterations: usize,
}

impl AutotuneReport {
    /// Iterations an exhaustive sweep would have spent to measure every
    /// candidate at the final round's fidelity.
    pub fn exhaustive_equivalent_iterations(&self) -> usize {
        self.num_candidates * self.final_round_iterations
    }
}

impl Autotuner {
    /// The fleet-shape candidates: symmetric splits plus validated extras.
    pub fn candidates(&self) -> Vec<(usize, usize)> {
        candidate_configs(self.worker_cores, &self.extra_configs)
    }

    /// The full search space: fleet shapes × dispatch modes.
    pub fn candidate_space(&self) -> Vec<((usize, usize), DispatchMode)> {
        let modes = if self.dispatch_modes.is_empty() {
            vec![DispatchMode::Centralized]
        } else {
            self.dispatch_modes.clone()
        };
        self.candidates()
            .into_iter()
            .flat_map(|cfg| modes.iter().map(move |&m| (cfg, m)))
            .collect()
    }

    /// Run the successive-halving search.
    pub fn search(&self, graph: &Graph, env: &SimEnv) -> AutotuneReport {
        let candidates = self.candidate_space();
        assert!(!candidates.is_empty(), "no parallel-setting candidates to search");
        let n = candidates.len();
        let mut acc: Vec<Welford> = vec![Welford::new(); n];
        let mut iters_done: Vec<u64> = vec![0; n];
        let mut alive: Vec<usize> = (0..n).collect();
        let mut per_round = self.initial_iterations.max(1);
        let mut rounds: Vec<AutotuneRound> = Vec::new();
        let mut total = 0usize;
        loop {
            for &ci in &alive {
                let ((executors, threads_per), dispatch) = candidates[ci];
                for _ in 0..per_round {
                    // same per-iteration seed schedule as the flat
                    // profiler (iteration k ⇒ seed ^ (k << 8)), continued
                    // across rounds so a survivor's later samples are
                    // fresh draws, not replays
                    let env_i = SimEnv {
                        cost: env.cost.clone(),
                        seed: env.seed ^ (iters_done[ci] << 8),
                    };
                    let result = GraphiEngine::new(executors, threads_per)
                        .with_dispatch(dispatch)
                        .run(graph, &env_i);
                    acc[ci].push(result.makespan_us);
                    iters_done[ci] += 1;
                    total += 1;
                }
            }
            alive.sort_by(|&a, &b| acc[a].mean().total_cmp(&acc[b].mean()));
            let measurements: Vec<ConfigMeasurement> = alive
                .iter()
                .map(|&ci| ConfigMeasurement {
                    executors: candidates[ci].0 .0,
                    threads_per: candidates[ci].0 .1,
                    dispatch: candidates[ci].1,
                    mean_makespan_us: acc[ci].mean(),
                    std_us: acc[ci].std(),
                })
                .collect();
            let keep = (alive.len() / 2).max(1);
            let survivors: Vec<((usize, usize), DispatchMode)> =
                alive.iter().take(keep).map(|&ci| candidates[ci]).collect();
            let finished = alive.len() == 1;
            rounds.push(AutotuneRound { iterations: per_round, measurements, survivors });
            if finished {
                break;
            }
            alive.truncate(keep);
            if alive.len() == 1 {
                break;
            }
            per_round = (per_round * 2).min(self.max_iterations.max(1));
        }
        let best_ci = alive[0];
        let (best, best_dispatch) = candidates[best_ci];
        let final_round_iterations = rounds.last().map(|r| r.iterations).unwrap_or(1);
        // §4.2's second job, at the surviving winner's team size.
        let durations_us = Profiler {
            iterations: self.duration_iterations.max(1),
            worker_cores: self.worker_cores,
            extra_configs: Vec::new(),
        }
        .estimate_durations(graph, env, best.1);
        let best_makespan_us = acc[best_ci].mean();
        let (phase_plan, phase_makespan_us, phase_refine_iterations) =
            if self.phase_search && self.dispatch_modes.len() >= 2 {
                self.refine_phases(graph, env, best, best_dispatch, best_makespan_us)
            } else {
                (None, None, 0)
            };
        let (width_plan, width_makespan_us, width_refine_iterations) = if self.width_search {
            self.refine_widths(graph, env, best, best_dispatch, best_makespan_us)
        } else {
            (None, None, 0)
        };
        AutotuneReport {
            best,
            best_dispatch,
            best_makespan_us,
            durations_us,
            rounds,
            total_profile_iterations: total,
            final_round_iterations,
            num_candidates: n,
            phase_plan,
            phase_makespan_us,
            phase_refine_iterations,
            width_plan,
            width_makespan_us,
            width_refine_iterations,
        }
    }

    /// The per-phase axis: split `graph` into width phases at the winning
    /// executor count, start from the uniform winner's plan, and greedily
    /// flip one phase's mode at a time (one sweep; every evaluation runs
    /// phased at the same eval seed, so the flips *and* the adoption gate
    /// are paired comparisons). The plan is adopted only when it actually
    /// mixes modes, strictly beats the **phased uniform baseline**
    /// (same harness, same seed — the apples-to-apples gate), and also
    /// beats the uniform winner's halving-search mean (a cross-check so a
    /// plan that merely out-runs the barrier-paying baseline, while losing
    /// to the plain uniform run, is never persisted). Otherwise the
    /// uniform winner stands and no plan is persisted.
    fn refine_phases(
        &self,
        graph: &Graph,
        env: &SimEnv,
        fleet: (usize, usize),
        uniform_mode: DispatchMode,
        uniform_makespan_us: f64,
    ) -> (Option<PhasePlan>, Option<f64>, usize) {
        // a depth is "wide" when it offers at least one ready op per
        // executor — below that the centralized scheduler keeps up and its
        // LW lane wins; above it dispatch throughput matters
        let threshold = fleet.0.max(2);
        let phases = width_phases(graph, threshold);
        if phases.len() < 2 {
            return (None, None, 0);
        }
        let eval_env = SimEnv { cost: env.cost.clone(), seed: env.seed ^ 0x9A5E };
        let mut iterations = 0usize;
        let mut run = |modes: &[DispatchMode]| -> f64 {
            iterations += 1;
            GraphiEngine::new(fleet.0, fleet.1)
                .with_phase_plan(PhasePlan { threshold, modes: modes.to_vec() })
                .run(graph, &eval_env)
                .makespan_us
        };
        let mut modes = vec![uniform_mode; phases.len()];
        let baseline_span = run(&modes);
        let mut best_span = baseline_span;
        for i in 0..modes.len() {
            let original = modes[i];
            modes[i] = original.other();
            let span = run(&modes);
            if span < best_span {
                best_span = span;
            } else {
                modes[i] = original;
            }
        }
        let mixes = modes.iter().any(|&m| m != uniform_mode);
        if mixes && best_span < baseline_span && best_span < uniform_makespan_us {
            (Some(PhasePlan { threshold, modes }), Some(best_span), iterations)
        } else {
            (None, None, iterations)
        }
    }

    /// The moldable-width axis: starting from the identity plan, greedily
    /// raise each op class's gang width through the powers of two (capped
    /// at the winning executor count and [`MAX_WIDTH`]), keeping a step
    /// only when its phased-free, same-seed evaluation strictly improves.
    /// Classes absent from the graph — and Tiny, which the runtime forces
    /// to width 1 — are skipped. The plan is adopted only when it is
    /// non-uniform, strictly beats the width-1 baseline at the eval seed
    /// (the paired comparison), *and* beats the uniform winner's
    /// halving-search mean (the same cross-seed sanity gate the phase
    /// search applies). Otherwise width 1 stands and no plan is persisted.
    fn refine_widths(
        &self,
        graph: &Graph,
        env: &SimEnv,
        fleet: (usize, usize),
        dispatch: DispatchMode,
        uniform_makespan_us: f64,
    ) -> (Option<WidthPlan>, Option<f64>, usize) {
        let max_w = (fleet.0 as u32).min(MAX_WIDTH);
        if max_w < 2 {
            return (None, None, 0);
        }
        let eval_env = SimEnv { cost: env.cost.clone(), seed: env.seed ^ 0x71D7 };
        let mut iterations = 0usize;
        let mut run = |plan: &WidthPlan| -> f64 {
            iterations += 1;
            GraphiEngine::new(fleet.0, fleet.1)
                .with_dispatch(dispatch)
                .with_width_plan(plan.clone())
                .run(graph, &eval_env)
                .makespan_us
        };
        // classes with at least one non-tiny op: a width for an absent
        // class changes nothing and would waste full simulated runs
        let mut present = [false; OpClass::COUNT];
        for node in graph.nodes() {
            if !node.kind.is_tiny() {
                present[node.kind.class().index()] = true;
            }
        }
        let mut plan = WidthPlan::uniform(1);
        // the uniform(1) evaluation runs the width-free paths byte-for-
        // byte, so this baseline is exactly "the winner without molding"
        let baseline_span = run(&plan);
        let mut best_span = baseline_span;
        for class in OpClass::ALL {
            if class == OpClass::Tiny || !present[class.index()] {
                continue;
            }
            let mut w = 2u32;
            while w <= max_w {
                let mut candidate = plan.clone();
                candidate.set(class, w);
                let span = run(&candidate);
                if span < best_span {
                    best_span = span;
                    plan = candidate;
                }
                w *= 2;
            }
        }
        if !plan.is_uniform_one() && best_span < baseline_span && best_span < uniform_makespan_us
        {
            (Some(plan), Some(best_span), iterations)
        } else {
            (None, None, iterations)
        }
    }

    /// Render the search trace as a table.
    pub fn render(report: &AutotuneReport) -> String {
        let mode_tag = |m: DispatchMode| match m {
            DispatchMode::Centralized => "",
            DispatchMode::Decentralized => "/d",
        };
        let mut t = crate::util::table::Table::new(&[
            "round", "iters", "alive", "best config", "best makespan", "std",
        ]);
        for (i, round) in report.rounds.iter().enumerate() {
            let best = &round.measurements[0];
            t.row(&[
                i.to_string(),
                round.iterations.to_string(),
                round.measurements.len().to_string(),
                format!("{}x{}{}", best.executors, best.threads_per, mode_tag(best.dispatch)),
                crate::util::fmt_us(best.mean_makespan_us),
                crate::util::fmt_us(best.std_us),
            ]);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "winner {}x{} ({} dispatch) after {} profiling iterations \
             (exhaustive sweep at the same fidelity: {})\n",
            report.best.0,
            report.best.1,
            report.best_dispatch.name(),
            report.total_profile_iterations,
            report.exhaustive_equivalent_iterations(),
        ));
        match (&report.phase_plan, report.phase_makespan_us) {
            (Some(plan), Some(span)) => out.push_str(&format!(
                "per-phase plan {} beats the uniform winner: {} vs {} \
                 ({} refinement runs)\n",
                plan.render(),
                crate::util::fmt_us(span),
                crate::util::fmt_us(report.best_makespan_us),
                report.phase_refine_iterations,
            )),
            _ if report.phase_refine_iterations > 0 => out.push_str(&format!(
                "per-phase search kept the uniform winner ({} refinement runs)\n",
                report.phase_refine_iterations
            )),
            _ => {}
        }
        match (&report.width_plan, report.width_makespan_us) {
            (Some(plan), Some(span)) => out.push_str(&format!(
                "gang-width plan [{}] beats width 1: {} vs {} ({} refinement runs)\n",
                plan.render(),
                crate::util::fmt_us(span),
                crate::util::fmt_us(report.best_makespan_us),
                report.width_refine_iterations,
            )),
            _ if report.width_refine_iterations > 0 => out.push_str(&format!(
                "gang-width search kept width 1 ({} refinement runs)\n",
                report.width_refine_iterations
            )),
            _ => {}
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{self, ModelKind, ModelSize};

    const EXTRAS: [(usize, usize); 2] = [(3, 21), (6, 10)];

    fn tuner() -> Autotuner {
        Autotuner { extra_configs: EXTRAS.to_vec(), ..Default::default() }
    }

    /// PR-2 behaviour: the search restricted to the centralized axis.
    fn centralized_tuner() -> Autotuner {
        Autotuner {
            dispatch_modes: vec![DispatchMode::Centralized],
            ..tuner()
        }
    }

    #[test]
    fn halving_schedule_shrinks_candidates_and_doubles_iterations() {
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        // 9 fleet shapes × 2 dispatch modes = 18 candidates
        let report = tuner().search(&g, &SimEnv::knl_deterministic());
        assert_eq!(report.num_candidates, 18);
        // 18 → 9 → 4 → 2 → 1 at 1, 2, 4, 8 iterations per round
        let alive: Vec<usize> = report.rounds.iter().map(|r| r.measurements.len()).collect();
        assert_eq!(alive, vec![18, 9, 4, 2]);
        let iters: Vec<usize> = report.rounds.iter().map(|r| r.iterations).collect();
        assert_eq!(iters, vec![1, 2, 4, 8]);
        assert_eq!(report.total_profile_iterations, 18 + 9 * 2 + 4 * 4 + 2 * 8);
        assert_eq!(report.final_round_iterations, 8);
        // strictly fewer than exhaustive at final fidelity (18 × 8 = 144)
        assert!(report.total_profile_iterations < report.exhaustive_equivalent_iterations());
    }

    #[test]
    fn centralized_only_axis_reproduces_the_pr2_schedule() {
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let report = centralized_tuner().search(&g, &SimEnv::knl_deterministic());
        assert_eq!(report.num_candidates, 9);
        let alive: Vec<usize> = report.rounds.iter().map(|r| r.measurements.len()).collect();
        assert_eq!(alive, vec![9, 4, 2]);
        assert_eq!(report.total_profile_iterations, 9 + 4 * 2 + 2 * 4);
        assert_eq!(report.best_dispatch, DispatchMode::Centralized);
    }

    #[test]
    fn deterministic_env_recovers_the_exhaustive_winner() {
        // noise-free: round-0 means are exact, so halving can never drop
        // the true optimum — restricted to the centralized axis, the
        // winner must equal the flat sweep's
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let env = SimEnv::knl_deterministic();
        let report = centralized_tuner().search(&g, &env);
        let exhaustive = Profiler {
            iterations: 1,
            worker_cores: 64,
            extra_configs: EXTRAS.to_vec(),
        }
        .profile(&g, &env);
        assert_eq!(report.best, exhaustive.best);
        assert_eq!(report.durations_us.len(), g.len());
        assert!(report.durations_us.iter().all(|&d| d > 0.0));
    }

    #[test]
    fn dispatch_axis_is_searched_and_never_loses_to_either_mode_alone() {
        // noise-free: the two-axis winner's measured makespan is the min
        // over the whole space, so it can be no worse than the best of
        // either single-mode search
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let env = SimEnv::knl_deterministic();
        let both = tuner().search(&g, &env);
        assert_eq!(both.num_candidates, 18);
        // round 0 measured both modes
        assert!(both.rounds[0].measurements.iter().any(|m| m.dispatch == DispatchMode::Centralized));
        assert!(both.rounds[0]
            .measurements
            .iter()
            .any(|m| m.dispatch == DispatchMode::Decentralized));
        let central = centralized_tuner().search(&g, &env);
        assert!(
            both.best_makespan_us <= central.best_makespan_us + 1e-9,
            "two-axis winner ({}) must be ≤ centralized-only winner ({})",
            both.best_makespan_us,
            central.best_makespan_us
        );
    }

    #[test]
    fn survivors_are_prefixes_of_measurements() {
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let report = tuner().search(&g, &SimEnv::knl(3));
        for round in &report.rounds {
            for (i, &(cfg, mode)) in round.survivors.iter().enumerate() {
                let m = &round.measurements[i];
                assert_eq!((m.executors, m.threads_per), cfg);
                assert_eq!(m.dispatch, mode);
            }
            // measurements sorted best-first
            for w in round.measurements.windows(2) {
                assert!(w[0].mean_makespan_us <= w[1].mean_makespan_us);
            }
        }
    }

    #[test]
    fn single_candidate_space_short_circuits() {
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let t = Autotuner {
            worker_cores: 1,
            dispatch_modes: vec![DispatchMode::Centralized],
            ..Default::default()
        };
        let report = t.search(&g, &SimEnv::knl_deterministic());
        assert_eq!(report.best, (1, 1));
        assert_eq!(report.total_profile_iterations, 1);
        assert_eq!(report.rounds.len(), 1);
    }

    #[test]
    fn centralized_only_axis_skips_the_phase_search() {
        // restricting the dispatch axis is an explicit caller choice; the
        // per-phase refinement must not sneak the other mode back in
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let report = centralized_tuner().search(&g, &SimEnv::knl_deterministic());
        assert_eq!(report.phase_refine_iterations, 0);
        assert_eq!(report.phase_plan, None);
    }

    #[test]
    fn phase_axis_is_searched_on_multi_phase_graphs() {
        // a graph with a clear narrow|wide|narrow structure: a chain head,
        // a wide band of small ops, a chain tail — the shape where the
        // phases differ enough that the flip search has something to find
        use crate::graph::op::{EwKind, OpKind};
        use crate::graph::GraphBuilder;
        let mut b = GraphBuilder::new();
        let mut prev = b.add("h0", OpKind::Elementwise { n: 50_000, arity: 1, kind: EwKind::Arith });
        for i in 1..6 {
            let n = b.add(format!("h{i}"), OpKind::Elementwise { n: 50_000, arity: 1, kind: EwKind::Arith });
            b.depend(prev, n);
            prev = n;
        }
        let mut band_prev = vec![prev];
        for layer in 0..12 {
            let mut this = Vec::new();
            for i in 0..24 {
                let n = b.add(
                    format!("w{layer}_{i}"),
                    OpKind::Elementwise { n: 2_000, arity: 2, kind: EwKind::Arith },
                );
                b.depend(band_prev[i % band_prev.len()], n);
                this.push(n);
            }
            band_prev = this;
        }
        let tail = b.add_after(
            "tail",
            OpKind::Elementwise { n: 50_000, arity: 1, kind: EwKind::Arith },
            &band_prev,
        );
        let mut last = tail;
        for i in 0..5 {
            let n = b.add(format!("t{i}"), OpKind::Elementwise { n: 50_000, arity: 1, kind: EwKind::Arith });
            b.depend(last, n);
            last = n;
        }
        let g = b.build().unwrap();
        let env = SimEnv::knl_deterministic();
        let report = tuner().search(&g, &env);
        // the winner has ≥2 executors, so the phase threshold splits the
        // chain ends from the wide band and the refinement actually ran
        let phases = crate::graph::width_phases(&g, report.best.0.max(2));
        if phases.len() >= 2 {
            assert!(
                report.phase_refine_iterations >= phases.len() + 1,
                "one baseline + one flip per phase, got {}",
                report.phase_refine_iterations
            );
        }
        // accounting contract: refinement never inflates the halving count
        assert_eq!(report.total_profile_iterations, 18 + 9 * 2 + 4 * 4 + 2 * 8);
        // if a plan was adopted it must line up with the graph, mix modes,
        // and measure strictly better than the uniform winner
        if let Some(plan) = &report.phase_plan {
            assert!(plan.matches(&g));
            assert!(plan.modes.iter().any(|&m| m != report.best_dispatch));
            assert!(report.phase_makespan_us.unwrap() < report.best_makespan_us);
        }
    }

    #[test]
    fn adopted_phase_plans_replay_to_their_reported_makespan() {
        // whatever the search decided, replaying the plan through the
        // engine at the same eval seed must reproduce the recorded number
        // (the artifact consumer relies on this determinism)
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        let env = SimEnv::knl_deterministic();
        let report = tuner().search(&g, &env);
        if let (Some(plan), Some(span)) = (&report.phase_plan, report.phase_makespan_us) {
            let eval_env = SimEnv { cost: env.cost.clone(), seed: env.seed ^ 0x9A5E };
            let replay = GraphiEngine::new(report.best.0, report.best.1)
                .with_phase_plan(plan.clone())
                .run(&g, &eval_env)
                .makespan_us;
            assert_eq!(replay, span);
        }
    }

    /// A wide band of small element-wise ops: `layers × 16` independent
    /// columns (the 640-node small-op shape at `layers = 40`).
    fn small_op_band(layers: usize) -> Graph {
        use crate::graph::op::{EwKind, OpKind};
        use crate::graph::GraphBuilder;
        let ew = OpKind::Elementwise { n: 2_000, arity: 2, kind: EwKind::Arith };
        let mut b = GraphBuilder::new();
        let mut prev: Vec<_> = (0..16).map(|i| b.add(format!("l0_{i}"), ew.clone())).collect();
        for layer in 1..layers {
            let this: Vec<_> = (0..16)
                .map(|i| {
                    let n = b.add(format!("l{layer}_{i}"), ew.clone());
                    b.depend(prev[i], n);
                    n
                })
                .collect();
            prev = this;
        }
        b.build().unwrap()
    }

    /// The moldable-ops acceptance shape: a narrow chain of
    /// saturation-8 GEMMs (the critical path) next to an independent
    /// wide small-op band. The band dominates the op count and pushes
    /// the uniform winner toward many small-team executors — which
    /// starves the GEMM chain; molding the GEMM class is the fix.
    fn gemm_chain_plus_band() -> Graph {
        use crate::graph::op::{EwKind, OpKind};
        use crate::graph::GraphBuilder;
        let gemm = OpKind::MatMul { m: 64, k: 512, n: 512 };
        let ew = OpKind::Elementwise { n: 2_000, arity: 2, kind: EwKind::Arith };
        let mut b = GraphBuilder::new();
        let mut prev = b.add("g0", gemm.clone());
        for i in 1..8 {
            let n = b.add(format!("g{i}"), gemm.clone());
            b.depend(prev, n);
            prev = n;
        }
        let mut band: Vec<_> = (0..16).map(|i| b.add(format!("b0_{i}"), ew.clone())).collect();
        for layer in 1..40 {
            let this: Vec<_> = (0..16)
                .map(|i| {
                    let n = b.add(format!("b{layer}_{i}"), ew.clone());
                    b.depend(band[i], n);
                    n
                })
                .collect();
            band = this;
        }
        b.build().unwrap()
    }

    /// Width-axis tuner: a 16-core space keeps the evaluations cheap and
    /// the compromise fleet shapes (2×8, 4×4, 8×2) in play.
    fn width_tuner() -> Autotuner {
        Autotuner { worker_cores: 16, width_search: true, ..Default::default() }
    }

    #[test]
    fn width_search_is_off_by_default_and_costs_nothing() {
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let report = tuner().search(&g, &SimEnv::knl_deterministic());
        assert_eq!(report.width_plan, None);
        assert_eq!(report.width_makespan_us, None);
        assert_eq!(report.width_refine_iterations, 0);
    }

    #[test]
    fn width_search_molds_starved_wide_gemms() {
        let g = gemm_chain_plus_band();
        let env = SimEnv::knl_deterministic();
        let report = width_tuner().search(&g, &env);
        assert!(report.width_refine_iterations > 0, "the width axis must have been searched");
        let plan = report
            .width_plan
            .clone()
            .expect("molding the starved GEMM chain must beat the uniform compromise");
        assert!(
            plan.width_for(OpClass::Gemm) > 1,
            "the chain's GEMMs want a gang: {}",
            plan.render()
        );
        assert_eq!(
            plan.width_for(OpClass::Elementwise),
            1,
            "small band ops must stay width 1: {}",
            plan.render()
        );
        let span = report.width_makespan_us.expect("paired with the plan");
        assert!(span < report.best_makespan_us, "adoption gate: strictly better than uniform");
        // replaying the plan at the eval seed reproduces the recorded
        // number — the artifact consumer relies on this determinism
        let eval_env = SimEnv { cost: env.cost.clone(), seed: env.seed ^ 0x71D7 };
        let replay = GraphiEngine::new(report.best.0, report.best.1)
            .with_dispatch(report.best_dispatch)
            .with_width_plan(plan)
            .run(&g, &eval_env)
            .makespan_us;
        assert_eq!(replay, span);
    }

    #[test]
    fn width_search_keeps_width_one_for_small_op_graphs() {
        // the 640-node small-op graph: halved inter-op concurrency plus
        // per-gang recruit cost always lose on µs-scale ops, so the
        // paired search must keep the identity plan
        let g = small_op_band(40);
        assert_eq!(g.len(), 640);
        let report = width_tuner().search(&g, &SimEnv::knl_deterministic());
        assert!(report.width_refine_iterations > 0, "the width axis must have been searched");
        assert_eq!(report.width_plan, None, "small ops must not be molded");
        assert_eq!(report.width_makespan_us, None);
    }

    #[test]
    fn render_names_the_winner() {
        let g = models::build(ModelKind::Mlp, ModelSize::Small);
        let report = tuner().search(&g, &SimEnv::knl_deterministic());
        let text = Autotuner::render(&report);
        assert!(text.contains("winner"));
        assert!(text.contains(&format!("{}x{}", report.best.0, report.best.1)));
        assert!(text.contains(report.best_dispatch.name()));
    }
}
