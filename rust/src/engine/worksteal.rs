//! Per-executor work-stealing deques for decentralized dispatch.
//!
//! The centralized architecture (§4/§5, PR 1) routes *every* completion
//! through a coordinator round-trip: executor → MPSC completion queue →
//! `DepTracker` → ready-heap → SPSC ring → executor. That serializes
//! dispatch on one thread, which caps throughput exactly where the paper
//! says small ops live or die (and Liu et al., arXiv:1810.08955, measured
//! the same wall at high op rates). In decentralized mode each executor
//! owns one of these deques; the executor that finishes op `n` resolves
//! `n`'s successors itself ([`crate::graph::AtomicDepTracker`]) and pushes
//! the newly-ready ops here — the common case never touches the
//! coordinator.
//!
//! # Which end is which, and why
//!
//! The deque is Chase–Lev-shaped: the **owner** pushes and pops at the
//! *bottom* with plain loads plus one release store, and **thieves** take
//! from the *top* with a CAS. Entries are the packed `u64`s of
//! [`super::ready::pack_entry`] — quantized critical-path level in the
//! high half, node id in the low half — so a single integer compare orders
//! any two entries by CP priority.
//!
//! * **Local pops take the LIFO (bottom) end for cache affinity.** The
//!   entries at the bottom are the successors this executor itself just
//!   triggered; their inputs are the op it just produced, still warm in
//!   its L1/L2. Each triggered batch is pushed in ascending key order, so
//!   the bottom entry is also the *highest-level* member of the newest
//!   batch — within a batch, LIFO popping is exactly CP-first.
//!
//! * **Steals take the high-priority end among *exposed* entries,
//!   approximating §4.3 CP-first at batch granularity.** Level values
//!   decrease monotonically along every dependency chain
//!   (`level(pred) = dur(pred) + max level(succ)` > `level(succ)` for
//!   positive durations), so every entry of an elder batch dominates every
//!   entry of its *descendant* batches further down the deque. Within one
//!   ascending-pushed batch the steal end exposes the batch's lower-level
//!   members first — the owner is draining that same batch's hot end from
//!   the other side, so thief and owner work toward each other. An idle
//!   executor compares the exposed top keys of *all* victims
//!   ([`steal_highest`]) and CASes the maximum away: the stolen op is the
//!   highest-priority entry any deque *exposes*, which keeps steals on
//!   elder (higher-level) generations instead of the freshest fringe.
//!   Exact global CP-first stealing would require a shared priority
//!   structure — precisely the serialized coordinator this module exists
//!   to remove; the differential suite checks semantics, and the bench
//!   checks the throughput this approximation buys.
//!
//! The deque is bounded (engines size it to the whole graph, so a push can
//! never fail in practice: each op is enqueued exactly once). Slots are
//! `AtomicU64`, which makes the classic Chase–Lev slot race benign safe
//! Rust: a thief that loses the CAS merely read a stale value it never
//! uses — no `unsafe` anywhere in this module.

use std::sync::atomic::{fence, AtomicIsize, AtomicU64, Ordering};

/// An atomic cursor on its own cache line (owner and thieves would
/// otherwise false-share).
#[repr(align(64))]
struct PaddedAtomicIsize(AtomicIsize);

/// Outcome of a steal attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Steal {
    /// Nothing visible to steal.
    Empty,
    /// Lost a race with the owner or another thief; the deque may still
    /// hold work — rescan.
    Retry,
    /// Took this entry.
    Success(u64),
}

/// Bounded Chase–Lev-style work-stealing deque of packed `u64` entries.
///
/// # Safety contract
///
/// At most one thread (the owner) may call [`push`](Self::push) /
/// [`pop`](Self::pop); any number of threads may call
/// [`steal`](Self::steal) / [`peek_top`](Self::peek_top) concurrently.
/// The engines uphold this by construction: executor `e` is the sole
/// owner of deque `e`.
pub struct WorkStealDeque {
    buf: Box<[AtomicU64]>,
    mask: usize,
    /// Owner end: next slot to write. Owner-written, thief-read.
    bottom: PaddedAtomicIsize,
    /// Steal end: oldest live slot. CASed by thieves and the owner's
    /// last-entry race.
    top: PaddedAtomicIsize,
}

impl WorkStealDeque {
    /// A deque holding at least `capacity` entries (rounded up to a power
    /// of two, minimum 2).
    pub fn new(capacity: usize) -> WorkStealDeque {
        let cap = capacity.max(2).next_power_of_two();
        let buf: Vec<AtomicU64> = (0..cap).map(|_| AtomicU64::new(0)).collect();
        WorkStealDeque {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            bottom: PaddedAtomicIsize(AtomicIsize::new(0)),
            top: PaddedAtomicIsize(AtomicIsize::new(0)),
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Owner: push an entry at the bottom; `Err(key)` if full.
    pub fn push(&self, key: u64) -> Result<(), u64> {
        let b = self.bottom.0.load(Ordering::Relaxed);
        let t = self.top.0.load(Ordering::Acquire);
        if b.wrapping_sub(t) >= self.buf.len() as isize {
            return Err(key);
        }
        self.buf[(b as usize) & self.mask].store(key, Ordering::Relaxed);
        // publish: thieves acquire-load `bottom`, which orders the slot
        // store above before their slot read
        self.bottom.0.store(b.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Owner: pop the most recently pushed entry (LIFO end), if any.
    pub fn pop(&self) -> Option<u64> {
        let b = self.bottom.0.load(Ordering::Relaxed).wrapping_sub(1);
        self.bottom.0.store(b, Ordering::Relaxed);
        // the SeqCst fence orders our `bottom` store against thieves' `top`
        // CAS: either we see their increment or they see our reservation
        fence(Ordering::SeqCst);
        let t = self.top.0.load(Ordering::Relaxed);
        if t <= b {
            let key = self.buf[(b as usize) & self.mask].load(Ordering::Relaxed);
            if t == b {
                // last entry: race thieves for it
                let won = self
                    .top
                    .0
                    .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.0.store(b.wrapping_add(1), Ordering::Relaxed);
                return won.then_some(key);
            }
            Some(key)
        } else {
            // already empty — undo the reservation
            self.bottom.0.store(b.wrapping_add(1), Ordering::Relaxed);
            None
        }
    }

    /// Thief: take the oldest (top / high-priority) entry.
    pub fn steal(&self) -> Steal {
        let t = self.top.0.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.0.load(Ordering::Acquire);
        if t < b {
            let key = self.buf[(t as usize) & self.mask].load(Ordering::Relaxed);
            if self
                .top
                .0
                .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
                .is_err()
            {
                return Steal::Retry;
            }
            Steal::Success(key)
        } else {
            Steal::Empty
        }
    }

    /// Thief: the key currently exposed at the steal end, if any. A racy
    /// hint — used only to rank victims; the subsequent [`steal`] CAS is
    /// what actually claims an entry.
    pub fn peek_top(&self) -> Option<u64> {
        let t = self.top.0.load(Ordering::Acquire);
        let b = self.bottom.0.load(Ordering::Acquire);
        if t < b {
            Some(self.buf[(t as usize) & self.mask].load(Ordering::Relaxed))
        } else {
            None
        }
    }

    /// Entries currently buffered (approximate under concurrency).
    pub fn len(&self) -> usize {
        let b = self.bottom.0.load(Ordering::Acquire);
        let t = self.top.0.load(Ordering::Acquire);
        b.wrapping_sub(t).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// CP-aware acquisition for executor `me`: pop the own deque's LIFO end,
/// and when it is empty steal the **highest-priority exposed entry** across
/// all victims ([`steal_highest`]). Returns the key and whether it was
/// stolen; `None` when every deque looks empty.
pub fn acquire(deques: &[WorkStealDeque], me: usize) -> Option<(u64, bool)> {
    if let Some(key) = deques[me].pop() {
        return Some((key, false));
    }
    steal_highest(deques, me).map(|key| (key, true))
}

/// The steal half of [`acquire`]: rank victims by their exposed top key
/// (max [`WorkStealDeque::peek_top`]) and CAS the best away. A lost CAS
/// (another thief got there first) rescans rather than giving up; the
/// scan terminates because each rescan only happens after some other
/// thread made progress. `None` when every victim looks empty.
pub fn steal_highest(deques: &[WorkStealDeque], me: usize) -> Option<u64> {
    loop {
        let mut best: Option<(usize, u64)> = None;
        for (v, d) in deques.iter().enumerate() {
            if v == me {
                continue;
            }
            if let Some(k) = d.peek_top() {
                if best.map_or(true, |(_, bk)| k > bk) {
                    best = Some((v, k));
                }
            }
        }
        let (victim, _) = best?;
        match deques[victim].steal() {
            Steal::Success(key) => return Some(key),
            Steal::Retry | Steal::Empty => continue,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_pops_lifo() {
        let d = WorkStealDeque::new(8);
        d.push(1).unwrap();
        d.push(2).unwrap();
        d.push(3).unwrap();
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.pop(), Some(2));
        d.push(4).unwrap();
        assert_eq!(d.pop(), Some(4));
        assert_eq!(d.pop(), Some(1));
        assert_eq!(d.pop(), None);
    }

    #[test]
    fn thief_steals_fifo_end() {
        let d = WorkStealDeque::new(8);
        d.push(1).unwrap();
        d.push(2).unwrap();
        d.push(3).unwrap();
        assert_eq!(d.peek_top(), Some(1));
        assert_eq!(d.steal(), Steal::Success(1));
        assert_eq!(d.steal(), Steal::Success(2));
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.steal(), Steal::Empty);
        assert_eq!(d.pop(), None);
    }

    #[test]
    fn capacity_bounded() {
        let d = WorkStealDeque::new(2);
        assert_eq!(d.capacity(), 2);
        d.push(1).unwrap();
        d.push(2).unwrap();
        assert_eq!(d.push(3), Err(3));
        assert_eq!(d.steal(), Steal::Success(1));
        d.push(3).unwrap();
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.pop(), Some(2));
    }

    #[test]
    fn wraparound_many_times() {
        let d = WorkStealDeque::new(2);
        for i in 0..1000u64 {
            d.push(i).unwrap();
            if i % 2 == 0 {
                assert_eq!(d.pop(), Some(i));
            } else {
                assert_eq!(d.steal(), Steal::Success(i));
            }
        }
        assert!(d.is_empty());
        assert_eq!(d.len(), 0);
    }

    #[test]
    fn acquire_prefers_local_then_highest_victim() {
        let deques: Vec<WorkStealDeque> = (0..3).map(|_| WorkStealDeque::new(8)).collect();
        deques[1].push(50).unwrap();
        deques[2].push(99).unwrap();
        deques[2].push(7).unwrap(); // bottom of deque 2; top stays 99
        // own work first
        deques[0].push(5).unwrap();
        assert_eq!(acquire(&deques, 0), Some((5, false)));
        // then the highest exposed top key across victims (99 on deque 2)
        assert_eq!(acquire(&deques, 0), Some((99, true)));
        assert_eq!(acquire(&deques, 0), Some((50, true)));
        assert_eq!(acquire(&deques, 0), Some((7, true)));
        assert_eq!(acquire(&deques, 0), None);
        assert_eq!(steal_highest(&deques, 0), None);
    }

    #[test]
    fn two_thieves_and_owner_account_every_entry_once() {
        use std::sync::atomic::{AtomicBool, AtomicU64 as AU64};
        let n = 100_000u64;
        let d = WorkStealDeque::new(1024);
        let produced_all = AtomicBool::new(false);
        let sum = AU64::new(0);
        let count = AU64::new(0);
        std::thread::scope(|s| {
            // two thieves drain the top
            for _ in 0..2 {
                s.spawn(|| loop {
                    match d.steal() {
                        Steal::Success(v) => {
                            sum.fetch_add(v, Ordering::Relaxed);
                            count.fetch_add(1, Ordering::Relaxed);
                        }
                        Steal::Retry => std::hint::spin_loop(),
                        Steal::Empty => {
                            if produced_all.load(Ordering::Acquire) && d.is_empty() {
                                return;
                            }
                            std::thread::yield_now();
                        }
                    }
                });
            }
            // the owner pushes 1..=n, popping occasionally
            for i in 1..=n {
                let mut key = i;
                loop {
                    match d.push(key) {
                        Ok(()) => break,
                        Err(back) => {
                            key = back;
                            // full: help drain from the owner end
                            if let Some(v) = d.pop() {
                                sum.fetch_add(v, Ordering::Relaxed);
                                count.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                if i % 7 == 0 {
                    if let Some(v) = d.pop() {
                        sum.fetch_add(v, Ordering::Relaxed);
                        count.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            // drain the remainder from the owner end, then signal
            while let Some(v) = d.pop() {
                sum.fetch_add(v, Ordering::Relaxed);
                count.fetch_add(1, Ordering::Relaxed);
            }
            produced_all.store(true, Ordering::Release);
        });
        assert_eq!(count.load(Ordering::Relaxed), n, "every entry taken exactly once");
        assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
    }
}
