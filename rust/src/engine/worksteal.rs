//! Per-executor work-stealing deques for decentralized dispatch.
//!
//! The centralized architecture (§4/§5, PR 1) routes *every* completion
//! through a coordinator round-trip: executor → MPSC completion queue →
//! `DepTracker` → ready-heap → SPSC ring → executor. That serializes
//! dispatch on one thread, which caps throughput exactly where the paper
//! says small ops live or die (and Liu et al., arXiv:1810.08955, measured
//! the same wall at high op rates). In decentralized mode each executor
//! owns one of these deques; the executor that finishes op `n` resolves
//! `n`'s successors itself ([`crate::graph::AtomicDepTracker`]) and pushes
//! the newly-ready ops here — the common case never touches the
//! coordinator.
//!
//! # Which end is which, and why
//!
//! The deque is Chase–Lev-shaped: the **owner** pushes and pops at the
//! *bottom* with plain loads plus one release store, and **thieves** take
//! from the *top* with a CAS. Entries are the packed `u64`s of
//! [`super::ready::pack_entry`] — quantized critical-path level in the
//! high half, node id in the low half — so a single integer compare orders
//! any two entries by CP priority.
//!
//! * **Local pops take the LIFO (bottom) end for cache affinity.** The
//!   entries at the bottom are the successors this executor itself just
//!   triggered; their inputs are the op it just produced, still warm in
//!   its L1/L2. Each triggered batch is pushed in ascending key order, so
//!   the bottom entry is also the *highest-level* member of the newest
//!   batch — within a batch, LIFO popping is exactly CP-first.
//!
//! * **Steals take the high-priority end among *exposed* entries,
//!   approximating §4.3 CP-first at batch granularity.** Level values
//!   decrease monotonically along every dependency chain
//!   (`level(pred) = dur(pred) + max level(succ)` > `level(succ)` for
//!   positive durations), so every entry of an elder batch dominates every
//!   entry of its *descendant* batches further down the deque. Within one
//!   ascending-pushed batch the steal end exposes the batch's lower-level
//!   members first — the owner is draining that same batch's hot end from
//!   the other side, so thief and owner work toward each other. An idle
//!   executor compares the exposed top keys of *all* victims
//!   ([`steal_highest`]) and CASes the maximum away: the stolen op is the
//!   highest-priority entry any deque *exposes*, which keeps steals on
//!   elder (higher-level) generations instead of the freshest fringe.
//!   Exact global CP-first stealing would require a shared priority
//!   structure — precisely the serialized coordinator this module exists
//!   to remove; the differential suite checks semantics, and the bench
//!   checks the throughput this approximation buys.
//!
//! The deque is bounded (engines size it to the whole graph, so a push can
//! never fail in practice: each op is enqueued exactly once). Slots are
//! `AtomicU64`, which makes the classic Chase–Lev slot race benign safe
//! Rust: a thief that loses the CAS merely read a stale value it never
//! uses — no `unsafe` anywhere in this module.
//!
//! # Poisoned-entry skip (fault domains)
//!
//! Entries are opaque `u64`s to the deque: there is no way (and no need)
//! to surgically remove a failed session's entries from the middle of a
//! Chase–Lev ring. The fault-tolerance contract lives one layer up, in
//! [`crate::runtime::fleet`]: when a session faults or is cancelled, its
//! remaining entries are **lazily discarded at pop time** — every pop or
//! steal resolves the packed key's session slot first and drops the entry
//! (without executing) if that session is poisoned. The only obligation
//! this module carries is the one it already has: every entry is handed
//! to exactly one consumer, so every poisoned entry is discarded exactly
//! once and the per-session live-entry accounting stays exact.
//! [`WorkStealDeque::len`] doubles as the watchdog's per-executor depth
//! probe when a no-progress dump is emitted.

use std::sync::atomic::{fence, AtomicIsize, AtomicU64, Ordering};

/// An atomic cursor on its own cache line (owner and thieves would
/// otherwise false-share).
#[repr(align(64))]
struct PaddedAtomicIsize(AtomicIsize);

/// Outcome of a steal attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Steal {
    /// Nothing visible to steal.
    Empty,
    /// Lost a race with the owner or another thief; the deque may still
    /// hold work — rescan.
    Retry,
    /// Took this entry.
    Success(u64),
}

/// Bounded Chase–Lev-style work-stealing deque of packed `u64` entries.
///
/// # Safety contract
///
/// At most one thread (the owner) may call [`push`](Self::push) /
/// [`pop`](Self::pop); any number of threads may call
/// [`steal`](Self::steal) / [`peek_top`](Self::peek_top) concurrently.
/// The engines uphold this by construction: executor `e` is the sole
/// owner of deque `e`.
pub struct WorkStealDeque {
    buf: Box<[AtomicU64]>,
    mask: usize,
    /// Owner end: next slot to write. Owner-written, thief-read.
    bottom: PaddedAtomicIsize,
    /// Steal end: oldest live slot. CASed by thieves and the owner's
    /// last-entry race.
    top: PaddedAtomicIsize,
}

impl WorkStealDeque {
    /// A deque holding at least `capacity` entries (rounded up to a power
    /// of two, minimum 2).
    pub fn new(capacity: usize) -> WorkStealDeque {
        let cap = capacity.max(2).next_power_of_two();
        let buf: Vec<AtomicU64> = (0..cap).map(|_| AtomicU64::new(0)).collect();
        WorkStealDeque {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            bottom: PaddedAtomicIsize(AtomicIsize::new(0)),
            top: PaddedAtomicIsize(AtomicIsize::new(0)),
        }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Owner: push an entry at the bottom; `Err(key)` if full.
    pub fn push(&self, key: u64) -> Result<(), u64> {
        let b = self.bottom.0.load(Ordering::Relaxed);
        let t = self.top.0.load(Ordering::Acquire);
        if b.wrapping_sub(t) >= self.buf.len() as isize {
            return Err(key);
        }
        self.buf[(b as usize) & self.mask].store(key, Ordering::Relaxed);
        // publish: thieves acquire-load `bottom`, which orders the slot
        // store above before their slot read
        self.bottom.0.store(b.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Owner: pop the most recently pushed entry (LIFO end), if any.
    pub fn pop(&self) -> Option<u64> {
        let b = self.bottom.0.load(Ordering::Relaxed).wrapping_sub(1);
        self.bottom.0.store(b, Ordering::Relaxed);
        // the SeqCst fence orders our `bottom` store against thieves' `top`
        // CAS: either we see their increment or they see our reservation
        fence(Ordering::SeqCst);
        let t = self.top.0.load(Ordering::Relaxed);
        if t <= b {
            let key = self.buf[(b as usize) & self.mask].load(Ordering::Relaxed);
            if t == b {
                // last entry: race thieves for it
                let won = self
                    .top
                    .0
                    .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
                    .is_ok();
                self.bottom.0.store(b.wrapping_add(1), Ordering::Relaxed);
                return won.then_some(key);
            }
            Some(key)
        } else {
            // already empty — undo the reservation
            self.bottom.0.store(b.wrapping_add(1), Ordering::Relaxed);
            None
        }
    }

    /// Thief: take the oldest (top / high-priority) entry.
    pub fn steal(&self) -> Steal {
        let t = self.top.0.load(Ordering::Acquire);
        fence(Ordering::SeqCst);
        let b = self.bottom.0.load(Ordering::Acquire);
        if t < b {
            let key = self.buf[(t as usize) & self.mask].load(Ordering::Relaxed);
            if self
                .top
                .0
                .compare_exchange(t, t.wrapping_add(1), Ordering::SeqCst, Ordering::Relaxed)
                .is_err()
            {
                return Steal::Retry;
            }
            Steal::Success(key)
        } else {
            Steal::Empty
        }
    }

    /// Thief: the key currently exposed at the steal end, if any. A racy
    /// hint — used only to rank victims; the subsequent [`steal`] CAS is
    /// what actually claims an entry.
    pub fn peek_top(&self) -> Option<u64> {
        let t = self.top.0.load(Ordering::Acquire);
        let b = self.bottom.0.load(Ordering::Acquire);
        if t < b {
            Some(self.buf[(t as usize) & self.mask].load(Ordering::Relaxed))
        } else {
            None
        }
    }

    /// Entries currently buffered (approximate under concurrency).
    pub fn len(&self) -> usize {
        let b = self.bottom.0.load(Ordering::Acquire);
        let t = self.top.0.load(Ordering::Acquire);
        b.wrapping_sub(t).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The quantized critical-path level carried in a packed deque key's high
/// half ([`super::ready::pack_entry`] layout). Victim ranking compares
/// *levels*, not whole keys: two entries on the same CP level differ only
/// by node id, and preferring a same-domain victim among level-ties is
/// exactly the topology-awareness §2/§9 asks for. The moldable gang-width
/// field ([`super::ready::ENTRY_WIDTH_BITS`]) lives strictly *below* the
/// level half in both packings, so CP ranking and the NUMA cross-domain
/// margin are width-oblivious by construction — the const-assert below
/// fails the build if the layouts ever drift.
#[inline]
pub fn entry_level(key: u64) -> u32 {
    (key >> super::ready::ENTRY_LEVEL_BITS) as u32
}

// The level shift above must agree with the packers' layouts: the level
// half starts right after slot+width+node (session keys) and width+node
// (single-graph keys).
const _: () = assert!(
    super::ready::ENTRY_LEVEL_BITS
        == super::ready::SESSION_SLOT_BITS
            + super::ready::ENTRY_WIDTH_BITS
            + super::ready::SESSION_NODE_BITS,
    "entry_level's shift no longer matches the session-key layout"
);
const _: () = assert!(
    super::ready::ENTRY_LEVEL_BITS
        == super::ready::ENTRY_WIDTH_BITS + super::ready::PLAIN_NODE_BITS,
    "entry_level's shift no longer matches the single-graph key layout"
);

/// Executor→NUMA-domain map plus the cross-domain steal policy, for
/// topology-aware victim ranking (§2's SNC modes; quadrant machines use
/// [`DomainMap::flat`], which makes every ranking decision identical to
/// the PR-3 domain-blind one).
///
/// The rule ([`steal_highest_numa`]): steal from the same-domain victim
/// exposing the highest key; go cross-domain only when the local domain is
/// dry, or a cross-domain top's *level* exceeds the local best's level by
/// more than `cross_margin` — i.e. the remote op is deeper on the critical
/// path by enough that eating the mesh crossing (priced by
/// `Calibration::steal_cross_domain_us` in the simulator) still wins.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DomainMap {
    domains: Vec<u32>,
    /// Margin a cross-domain top's quantized level must clear over the
    /// local best before it is preferred, in **units of the packed key's
    /// level field** — the top 32 bits of the order-preserving `f64`-bit
    /// map ([`super::ready::pack_entry`]), *not* a linear µs scale. One
    /// unit is ≈ a 2⁻²⁰ relative level difference (exponent bits dominate
    /// the field), so nonzero margins only discriminate between
    /// exact/near ties and everything else; they cannot express "X µs of
    /// critical path". The margin that matters in practice is **0**: stay
    /// local on level ties, cross on any strictly higher level — which is
    /// what every production call site uses
    /// ([`DomainMap::flat`]/[`DomainMap::of_fleet`] and the simulator).
    /// Larger values make the local preference coarsely stickier and are
    /// kept for experimentation (property-tested against the brute-force
    /// rule either way).
    pub cross_margin: u32,
}

impl DomainMap {
    pub fn new(domains: Vec<u32>, cross_margin: u32) -> DomainMap {
        DomainMap { domains, cross_margin }
    }

    /// Single-domain map: every victim ranks equally (quadrant mode, or
    /// a host whose topology is unknown).
    pub fn flat(executors: usize) -> DomainMap {
        DomainMap { domains: vec![0; executors], cross_margin: 0 }
    }

    /// Derive the map from a machine's fleet shape
    /// ([`crate::cost::machine::Machine::executor_domain_map`]).
    pub fn of_fleet(
        machine: &crate::cost::machine::Machine,
        executors: usize,
        threads_per: usize,
    ) -> DomainMap {
        DomainMap { domains: machine.executor_domain_map(executors, threads_per), cross_margin: 0 }
    }

    pub fn len(&self) -> usize {
        self.domains.len()
    }

    pub fn is_empty(&self) -> bool {
        self.domains.is_empty()
    }

    pub fn domain_of(&self, executor: usize) -> u32 {
        self.domains[executor]
    }

    pub fn same_domain(&self, a: usize, b: usize) -> bool {
        self.domains[a] == self.domains[b]
    }

    /// More than one distinct domain present?
    pub fn is_multi_domain(&self) -> bool {
        self.domains.windows(2).any(|w| w[0] != w[1])
    }
}

/// How an executor came by its next op — the accounting the runtime and
/// the simulator's cost model both need.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Acquire {
    /// Popped from the own deque's LIFO end.
    LocalPop,
    /// Stolen from a victim in the same NUMA domain.
    StealLocalDomain,
    /// Stolen across a domain boundary (pays the mesh-crossing surcharge).
    StealCrossDomain,
}

impl Acquire {
    pub fn is_steal(self) -> bool {
        self != Acquire::LocalPop
    }
}

/// CP-aware acquisition for executor `me`: pop the own deque's LIFO end,
/// and when it is empty steal the **highest-priority exposed entry** across
/// all victims ([`steal_highest`]). Returns the key and whether it was
/// stolen; `None` when every deque looks empty.
pub fn acquire(deques: &[WorkStealDeque], me: usize) -> Option<(u64, bool)> {
    if let Some(key) = deques[me].pop() {
        return Some((key, false));
    }
    steal_highest(deques, me).map(|key| (key, true))
}

/// Topology-aware [`acquire`]: same local-pop fast path, NUMA-ranked
/// stealing ([`steal_highest_numa`]) when the own deque is dry.
pub fn acquire_numa(
    deques: &[WorkStealDeque],
    me: usize,
    map: &DomainMap,
) -> Option<(u64, Acquire)> {
    if let Some(key) = deques[me].pop() {
        return Some((key, Acquire::LocalPop));
    }
    steal_highest_numa(deques, me, map)
}

/// The steal half of [`acquire_numa`]: rank victims by exposed top key
/// *within* `me`'s NUMA domain first, and cross the domain boundary only
/// when the local domain exposes nothing or a remote top's level beats the
/// local best by more than `map.cross_margin` (see [`DomainMap`]). Within
/// the chosen side the highest full key wins, first victim among exact
/// ties — the same deterministic rule [`steal_highest`] uses, so a
/// [`DomainMap::flat`] map reproduces it bit-for-bit. A lost CAS rescans;
/// `None` when every victim looks empty.
pub fn steal_highest_numa(
    deques: &[WorkStealDeque],
    me: usize,
    map: &DomainMap,
) -> Option<(u64, Acquire)> {
    debug_assert_eq!(deques.len(), map.len(), "one domain per executor");
    loop {
        let mut best_local: Option<(usize, u64)> = None;
        let mut best_remote: Option<(usize, u64)> = None;
        for (v, d) in deques.iter().enumerate() {
            if v == me {
                continue;
            }
            if let Some(k) = d.peek_top() {
                let best = if map.same_domain(me, v) { &mut best_local } else { &mut best_remote };
                if best.map_or(true, |(_, bk)| k > bk) {
                    *best = Some((v, k));
                }
            }
        }
        let (victim, kind) = match (best_local, best_remote) {
            (None, None) => return None,
            (Some((v, _)), None) => (v, Acquire::StealLocalDomain),
            (None, Some((v, _))) => (v, Acquire::StealCrossDomain),
            (Some((lv, lk)), Some((rv, rk))) => {
                if entry_level(rk) > entry_level(lk).saturating_add(map.cross_margin) {
                    (rv, Acquire::StealCrossDomain)
                } else {
                    (lv, Acquire::StealLocalDomain)
                }
            }
        };
        match deques[victim].steal() {
            Steal::Success(key) => return Some((key, kind)),
            Steal::Retry | Steal::Empty => continue,
        }
    }
}

/// The steal half of [`acquire`]: rank victims by their exposed top key
/// (max [`WorkStealDeque::peek_top`]) and CAS the best away. A lost CAS
/// (another thief got there first) rescans rather than giving up; the
/// scan terminates because each rescan only happens after some other
/// thread made progress. `None` when every victim looks empty.
pub fn steal_highest(deques: &[WorkStealDeque], me: usize) -> Option<u64> {
    loop {
        let mut best: Option<(usize, u64)> = None;
        for (v, d) in deques.iter().enumerate() {
            if v == me {
                continue;
            }
            if let Some(k) = d.peek_top() {
                if best.map_or(true, |(_, bk)| k > bk) {
                    best = Some((v, k));
                }
            }
        }
        let (victim, _) = best?;
        match deques[victim].steal() {
            Steal::Success(key) => return Some(key),
            Steal::Retry | Steal::Empty => continue,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_pops_lifo() {
        let d = WorkStealDeque::new(8);
        d.push(1).unwrap();
        d.push(2).unwrap();
        d.push(3).unwrap();
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.pop(), Some(2));
        d.push(4).unwrap();
        assert_eq!(d.pop(), Some(4));
        assert_eq!(d.pop(), Some(1));
        assert_eq!(d.pop(), None);
    }

    #[test]
    fn thief_steals_fifo_end() {
        let d = WorkStealDeque::new(8);
        d.push(1).unwrap();
        d.push(2).unwrap();
        d.push(3).unwrap();
        assert_eq!(d.peek_top(), Some(1));
        assert_eq!(d.steal(), Steal::Success(1));
        assert_eq!(d.steal(), Steal::Success(2));
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.steal(), Steal::Empty);
        assert_eq!(d.pop(), None);
    }

    #[test]
    fn capacity_bounded() {
        let d = WorkStealDeque::new(2);
        assert_eq!(d.capacity(), 2);
        d.push(1).unwrap();
        d.push(2).unwrap();
        assert_eq!(d.push(3), Err(3));
        assert_eq!(d.steal(), Steal::Success(1));
        d.push(3).unwrap();
        assert_eq!(d.pop(), Some(3));
        assert_eq!(d.pop(), Some(2));
    }

    #[test]
    fn wraparound_many_times() {
        let d = WorkStealDeque::new(2);
        for i in 0..1000u64 {
            d.push(i).unwrap();
            if i % 2 == 0 {
                assert_eq!(d.pop(), Some(i));
            } else {
                assert_eq!(d.steal(), Steal::Success(i));
            }
        }
        assert!(d.is_empty());
        assert_eq!(d.len(), 0);
    }

    #[test]
    fn acquire_prefers_local_then_highest_victim() {
        let deques: Vec<WorkStealDeque> = (0..3).map(|_| WorkStealDeque::new(8)).collect();
        deques[1].push(50).unwrap();
        deques[2].push(99).unwrap();
        deques[2].push(7).unwrap(); // bottom of deque 2; top stays 99
        // own work first
        deques[0].push(5).unwrap();
        assert_eq!(acquire(&deques, 0), Some((5, false)));
        // then the highest exposed top key across victims (99 on deque 2)
        assert_eq!(acquire(&deques, 0), Some((99, true)));
        assert_eq!(acquire(&deques, 0), Some((50, true)));
        assert_eq!(acquire(&deques, 0), Some((7, true)));
        assert_eq!(acquire(&deques, 0), None);
        assert_eq!(steal_highest(&deques, 0), None);
    }

    /// Keys with a controllable level half (`level << 32 | node`), the
    /// same layout as [`crate::engine::ready::pack_entry`].
    fn key(level: u32, node: u32) -> u64 {
        ((level as u64) << 32) | node as u64
    }

    #[test]
    fn entry_level_unpacks_the_high_half() {
        assert_eq!(entry_level(key(7, 3)), 7);
        assert_eq!(entry_level(key(u32::MAX, 0)), u32::MAX);
        assert_eq!(entry_level(0), 0);
    }

    #[test]
    fn gang_width_bits_never_disturb_level_ranking() {
        use crate::engine::ready::{
            pack_entry, pack_entry_wide, pack_session_entry, pack_session_entry_wide, MAX_WIDTH,
        };
        for level in [0.0f64, 1.5, 123.0, 1e9] {
            for w in 1..=MAX_WIDTH {
                assert_eq!(
                    entry_level(pack_entry_wide(level, 7, w)),
                    entry_level(pack_entry(level, 7)),
                );
                assert_eq!(
                    entry_level(pack_session_entry_wide(level, 3, 7, w)),
                    entry_level(pack_session_entry(level, 3, 7)),
                );
            }
        }
        // a strictly higher level still dominates any width difference,
        // so NUMA margin decisions are unchanged by widths
        assert!(
            entry_level(pack_entry_wide(9.0, 0, MAX_WIDTH)) > entry_level(pack_entry(5.0, 0))
        );
    }

    #[test]
    fn flat_domain_map_reproduces_domain_blind_stealing() {
        // same deque states, both rankings: the flat map must pick the
        // exact same victim sequence as the PR-3 domain-blind rule
        let mk = || {
            let deques: Vec<WorkStealDeque> = (0..4).map(|_| WorkStealDeque::new(8)).collect();
            deques[1].push(key(5, 1)).unwrap();
            deques[2].push(key(9, 2)).unwrap();
            deques[2].push(key(3, 22)).unwrap();
            deques[3].push(key(9, 1)).unwrap(); // level-ties with deque 2's top
            deques
        };
        let map = DomainMap::flat(4);
        assert!(!map.is_multi_domain());
        let (a, b) = (mk(), mk());
        let mut blind = Vec::new();
        while let Some(k) = steal_highest(&a, 0) {
            blind.push(k);
        }
        let mut numa = Vec::new();
        while let Some((k, kind)) = steal_highest_numa(&b, 0, &map) {
            assert_eq!(kind, Acquire::StealLocalDomain, "flat map has no remote domain");
            numa.push(k);
        }
        assert_eq!(blind, numa);
    }

    #[test]
    fn same_domain_victim_preferred_on_level_ties() {
        // me = 0 in domain 0 with victim 1; victims 2,3 in domain 1.
        // Remote tops tie or trail the local level → stay local.
        let deques: Vec<WorkStealDeque> = (0..4).map(|_| WorkStealDeque::new(8)).collect();
        let map = DomainMap::new(vec![0, 0, 1, 1], 0);
        deques[1].push(key(6, 1)).unwrap();
        deques[2].push(key(6, 99)).unwrap(); // same level, higher full key
        deques[3].push(key(5, 1)).unwrap();
        assert_eq!(
            steal_highest_numa(&deques, 0, &map),
            Some((key(6, 1), Acquire::StealLocalDomain)),
            "a level-tied remote top must not out-rank the local victim"
        );
    }

    #[test]
    fn cross_domain_steal_needs_a_level_win_beyond_the_margin() {
        let deques: Vec<WorkStealDeque> = (0..3).map(|_| WorkStealDeque::new(8)).collect();
        deques[1].push(key(4, 1)).unwrap(); // local (domain 0)
        deques[2].push(key(6, 2)).unwrap(); // remote (domain 1), 2 levels up
        // margin 0: remote's strictly higher level wins
        let sharp = DomainMap::new(vec![0, 0, 1], 0);
        assert_eq!(
            steal_highest_numa(&deques, 0, &sharp).unwrap().1,
            Acquire::StealCrossDomain
        );
        // margin 2: a 2-level lead is not *beyond* the margin → stay local
        let deques: Vec<WorkStealDeque> = (0..3).map(|_| WorkStealDeque::new(8)).collect();
        deques[1].push(key(4, 1)).unwrap();
        deques[2].push(key(6, 2)).unwrap();
        let sticky = DomainMap::new(vec![0, 0, 1], 2);
        assert_eq!(
            steal_highest_numa(&deques, 0, &sticky),
            Some((key(4, 1), Acquire::StealLocalDomain))
        );
    }

    #[test]
    fn dry_local_domain_falls_through_to_remote() {
        let deques: Vec<WorkStealDeque> = (0..3).map(|_| WorkStealDeque::new(8)).collect();
        let map = DomainMap::new(vec![0, 0, 1], 0);
        deques[2].push(key(1, 7)).unwrap(); // only remote work exists
        assert_eq!(
            acquire_numa(&deques, 0, &map),
            Some((key(1, 7), Acquire::StealCrossDomain))
        );
        assert_eq!(acquire_numa(&deques, 0, &map), None);
        // own deque still wins over everything
        deques[0].push(key(0, 1)).unwrap();
        deques[2].push(key(9, 9)).unwrap();
        assert_eq!(
            acquire_numa(&deques, 0, &map),
            Some((key(0, 1), Acquire::LocalPop))
        );
    }

    #[test]
    fn domain_map_of_fleet_matches_machine_striping() {
        let snc = crate::cost::machine::Machine::knl7250_snc4();
        let map = DomainMap::of_fleet(&snc, 8, 8);
        assert_eq!(map.len(), 8);
        assert!(map.is_multi_domain());
        assert!(map.same_domain(0, 1));
        assert!(!map.same_domain(0, 7));
        let quad = crate::cost::machine::Machine::knl7250();
        assert!(!DomainMap::of_fleet(&quad, 8, 8).is_multi_domain());
    }

    #[test]
    fn two_thieves_and_owner_account_every_entry_once() {
        use std::sync::atomic::{AtomicBool, AtomicU64 as AU64};
        let n = 100_000u64;
        let d = WorkStealDeque::new(1024);
        let produced_all = AtomicBool::new(false);
        let sum = AU64::new(0);
        let count = AU64::new(0);
        std::thread::scope(|s| {
            // two thieves drain the top
            for _ in 0..2 {
                s.spawn(|| loop {
                    match d.steal() {
                        Steal::Success(v) => {
                            sum.fetch_add(v, Ordering::Relaxed);
                            count.fetch_add(1, Ordering::Relaxed);
                        }
                        Steal::Retry => std::hint::spin_loop(),
                        Steal::Empty => {
                            if produced_all.load(Ordering::Acquire) && d.is_empty() {
                                return;
                            }
                            std::thread::yield_now();
                        }
                    }
                });
            }
            // the owner pushes 1..=n, popping occasionally
            for i in 1..=n {
                let mut key = i;
                loop {
                    match d.push(key) {
                        Ok(()) => break,
                        Err(back) => {
                            key = back;
                            // full: help drain from the owner end
                            if let Some(v) = d.pop() {
                                sum.fetch_add(v, Ordering::Relaxed);
                                count.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                if i % 7 == 0 {
                    if let Some(v) = d.pop() {
                        sum.fetch_add(v, Ordering::Relaxed);
                        count.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            // drain the remainder from the owner end, then signal
            while let Some(v) = d.pop() {
                sum.fetch_add(v, Ordering::Relaxed);
                count.fetch_add(1, Ordering::Relaxed);
            }
            produced_all.store(true, Ordering::Release);
        });
        assert_eq!(count.load(Ordering::Relaxed), n, "every entry taken exactly once");
        assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
    }
}
