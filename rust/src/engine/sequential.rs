//! Sequential baseline: one executor runs the graph in topological order
//! with the full worker-core team (§2's "conventional way").
//!
//! This is the `S64` column of Fig 6 — the engine most frameworks default
//! to, optimal only when ops are large enough to use the whole chip.

use crate::graph::Graph;

use super::trace::OpRecord;
use super::{Engine, EngineMetrics, RunResult, SimEnv};

/// Sequential interpreter with a configurable team size.
#[derive(Debug, Clone)]
pub struct SequentialEngine {
    /// Threads the single executor uses (the paper's S64 uses all 64
    /// worker cores).
    pub threads: usize,
}

impl SequentialEngine {
    pub fn new(threads: usize) -> SequentialEngine {
        SequentialEngine { threads }
    }
}

impl Engine for SequentialEngine {
    fn name(&self) -> String {
        format!("sequential-{}t", self.threads)
    }

    fn run(&self, graph: &Graph, env: &SimEnv) -> RunResult {
        let interference = env.interference();
        let mut rng = env.rng();
        let mut now = 0.0f64;
        let mut records = Vec::with_capacity(graph.len());
        let mut busy = 0.0f64;
        for &node in &graph.topo_order() {
            let kind = &graph.node(node).kind;
            let dur = env.cost.duration_us(kind, self.threads) * interference.noise(&mut rng);
            records.push(OpRecord { node, executor: 0, start_us: now, end_us: now + dur });
            now += dur;
            busy += dur;
        }
        let result = RunResult {
            makespan_us: now,
            records,
            metrics: EngineMetrics {
                dispatches: graph.len() as u64,
                executor_busy_us: vec![busy],
                ..Default::default()
            },
        };
        debug_assert!(result.validate(graph).is_ok());
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp::{build as mlp, MlpConfig};

    #[test]
    fn sequential_is_valid_and_fully_utilized() {
        let g = mlp(&MlpConfig::default());
        let r = SequentialEngine::new(64).run(&g, &SimEnv::knl_deterministic());
        r.validate(&g).unwrap();
        assert!((r.metrics.utilization(r.makespan_us) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn makespan_equals_sum_of_durations() {
        let g = mlp(&MlpConfig::default());
        let env = SimEnv::knl_deterministic();
        let r = SequentialEngine::new(64).run(&g, &env);
        let expected: f64 = g
            .nodes()
            .iter()
            .map(|n| env.cost.duration_us(&n.kind, 64))
            .sum();
        assert!((r.makespan_us - expected).abs() < 1e-6);
    }
}
