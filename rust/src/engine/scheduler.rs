//! The centralized scheduler's decision core (§4.3, §5.2).
//!
//! Two pieces the paper calls out explicitly:
//!
//! * the **idle-executor bitmap** — "the executor states are represented
//!   as a bit map … We use bit-scan intrinsics to find the number of
//!   trailing zeros, which corresponds to the first executor now available"
//!   (`u128::trailing_zeros` compiles to `tzcnt`);
//! * the **dispatch loop** — pop the max-level ready op, find the first
//!   idle executor, push into that executor's private buffer.
//!
//! The loop itself lives in each engine (simulated vs threaded), built on
//! these primitives plus [`super::ready`].

/// Executor idle/busy states as a bitmap (1 = idle).
#[derive(Debug, Clone)]
pub struct IdleBitmap {
    bits: u128,
    n: usize,
}

impl IdleBitmap {
    /// All `n` executors idle. Supports up to 128 executors (the paper's
    /// largest fleet is 64).
    pub fn new(n: usize) -> IdleBitmap {
        assert!(n <= 128, "at most 128 executors supported, got {n}");
        let bits = if n == 128 { u128::MAX } else { (1u128 << n) - 1 };
        IdleBitmap { bits, n }
    }

    /// First idle executor (lowest index), via bit-scan.
    #[inline]
    pub fn first_idle(&self) -> Option<usize> {
        if self.bits == 0 {
            None
        } else {
            Some(self.bits.trailing_zeros() as usize)
        }
    }

    #[inline]
    pub fn set_busy(&mut self, e: usize) {
        debug_assert!(e < self.n);
        debug_assert!(self.is_idle(e), "executor {e} already busy");
        self.bits &= !(1u128 << e);
    }

    #[inline]
    pub fn set_idle(&mut self, e: usize) {
        debug_assert!(e < self.n);
        debug_assert!(!self.is_idle(e), "executor {e} already idle");
        self.bits |= 1u128 << e;
    }

    #[inline]
    pub fn is_idle(&self, e: usize) -> bool {
        self.bits & (1u128 << e) != 0
    }

    #[inline]
    pub fn any_idle(&self) -> bool {
        self.bits != 0
    }

    pub fn count_idle(&self) -> usize {
        self.bits.count_ones() as usize
    }

    pub fn executors(&self) -> usize {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_all_idle() {
        let b = IdleBitmap::new(8);
        assert_eq!(b.count_idle(), 8);
        assert_eq!(b.first_idle(), Some(0));
    }

    #[test]
    fn busy_idle_roundtrip() {
        let mut b = IdleBitmap::new(4);
        b.set_busy(0);
        b.set_busy(1);
        assert_eq!(b.first_idle(), Some(2));
        assert!(!b.is_idle(0));
        b.set_idle(0);
        assert_eq!(b.first_idle(), Some(0));
        assert_eq!(b.count_idle(), 3);
    }

    #[test]
    fn exhaustion() {
        let mut b = IdleBitmap::new(2);
        b.set_busy(0);
        b.set_busy(1);
        assert_eq!(b.first_idle(), None);
        assert!(!b.any_idle());
    }

    #[test]
    fn supports_64_executors() {
        // the paper's largest fleet: 64 executors × 1 thread
        let mut b = IdleBitmap::new(64);
        for e in 0..63 {
            b.set_busy(e);
        }
        assert_eq!(b.first_idle(), Some(63));
    }

    #[test]
    fn supports_128() {
        let mut b = IdleBitmap::new(128);
        assert_eq!(b.count_idle(), 128);
        b.set_busy(127);
        assert_eq!(b.count_idle(), 127);
    }

    #[test]
    #[should_panic(expected = "at most 128")]
    fn too_many_rejected() {
        IdleBitmap::new(129);
    }
}
