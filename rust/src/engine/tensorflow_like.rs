//! TensorFlow-1.2-style baseline for the Fig 5 comparison.
//!
//! Models the mechanisms §3.1/§7.2 blame for TensorFlow's gap on the
//! manycore CPU:
//!
//! * **no thread placement control** — OS-managed threads, collisions and
//!   migration stalls;
//! * **oversubscription** — Eigen's own thread pool coexists with the MKL
//!   OpenMP pool, so software threads ≈ inter-op executors × team + a
//!   whole extra core-count worth of Eigen workers;
//! * **Eigen element-wise chunking** — element-wise ops are split into
//!   small chunks managed in a centralized job queue, adding per-chunk
//!   overhead and queue contention (worst for medium sizes, §7.2);
//! * **MKL convolutions** — slower than Graphi's LIBXSMM for the small
//!   convs in PathNet (`duration_us_mkl`);
//! * **naive shared ready queue** — same FIFO + contention as
//!   [`super::naive`].

use crate::cost::Interference;
use crate::graph::op::OpKind;
use crate::graph::{Graph, NodeId};
use crate::sim::{BandwidthArbiter, EventQueue};
use crate::util::rng::Rng;

use super::policies::Policy;
use super::ready::{DepTracker, ReadySet};
use super::scheduler::IdleBitmap;
use super::trace::OpRecord;
use super::{Engine, EngineMetrics, RunResult, SimEnv};

/// TensorFlow-like engine configuration.
#[derive(Debug, Clone)]
pub struct TensorFlowLikeEngine {
    /// inter_op_parallelism_threads — concurrent op executors.
    pub inter_op: usize,
    /// intra_op team size per op.
    pub intra_op: usize,
}

impl TensorFlowLikeEngine {
    pub fn new(inter_op: usize, intra_op: usize) -> TensorFlowLikeEngine {
        TensorFlowLikeEngine { inter_op, intra_op }
    }

    /// The best-effort tuned configuration the paper grants TensorFlow
    /// ("results of the best parallelization settings for both"): a small
    /// inter-op pool with MKL-sized teams.
    pub fn tuned_for(graph_width: usize, cores: usize) -> TensorFlowLikeEngine {
        let inter = graph_width.clamp(2, 8);
        TensorFlowLikeEngine { inter_op: inter, intra_op: (cores / inter).max(1) }
    }
}

enum Ev {
    Done { node: NodeId, exec: u32, bw_token: u64 },
}

impl Engine for TensorFlowLikeEngine {
    fn name(&self) -> String {
        format!("tensorflow-like-{}x{}", self.inter_op, self.intra_op)
    }

    fn run(&self, graph: &Graph, env: &SimEnv) -> RunResult {
        let cost = &env.cost;
        let interference = Interference::new(cost.cal.clone());
        let mut rng: Rng = env.rng();
        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut deps = DepTracker::new(graph);
        // FIFO never consults levels, so none are allocated
        let mut ready = ReadySet::new(Policy::Fifo, Vec::<f64>::new(), env.seed);
        let mut idle = IdleBitmap::new(self.inter_op);
        let mut bw = BandwidthArbiter::new(cost.machine.mcdram_bw);
        let mut records = Vec::with_capacity(graph.len());
        let mut metrics = EngineMetrics {
            executor_busy_us: vec![0.0; self.inter_op],
            ..Default::default()
        };
        let mut ready_at = vec![0.0f64; graph.len()];

        // oversubscription: MKL/OpenMP teams + the Eigen pool. The Eigen
        // workers are only runnable while element-wise chunks are in
        // flight, so they count at half weight.
        let total_threads = self.inter_op * self.intra_op + cost.machine.cores / 2;
        let cal = cost.cal.clone();
        // serialized shared ready queue, as in `naive.rs`
        let mut queue_free_us = 0.0f64;

        macro_rules! dispatch {
            ($now:expr) => {
                while !ready.is_empty() && idle.any_idle() {
                    let e = idle.first_idle().unwrap();
                    let pollers = idle.count_idle();
                    let dq = interference.shared_queue_dequeue_us(pollers)
                        + interference.wake_latency_us();
                    let dq_start = queue_free_us.max($now);
                    queue_free_us = dq_start + dq;
                    metrics.contention_us += queue_free_us - $now - cal.queue_base_us;
                    metrics.dispatches += 1;
                    idle.set_busy(e);
                    let node = ready.pop().unwrap();
                    let kind = &graph.node(node).kind;
                    let start = queue_free_us;
                    // MKL conv path (no LIBXSMM in stock TF 1.2)
                    let mut dur = cost.duration_us_mkl(kind, self.intra_op)
                        * interference.noise(&mut rng);
                    // Eigen chunked element-wise execution through the
                    // centralized job queue: chunks execute in waves of
                    // `workers`; every wave pays one queue round-trip. For
                    // small ops (few chunks) this is a fixed latency tax —
                    // the §7.2 effect that hits LSTM hardest; for huge ops
                    // it amortizes to a few percent.
                    if let OpKind::Elementwise { n, .. } = kind {
                        let chunks = n.div_ceil(cal.eigen_chunk_elems);
                        let workers = self.intra_op.max(1) as u64;
                        let waves = chunks.div_ceil(workers) as f64;
                        let chunk_overhead = waves
                            * (cal.eigen_chunk_overhead_us
                                + interference.shared_queue_dequeue_us(self.intra_op.min(8)));
                        metrics.contention_us += chunk_overhead;
                        dur += chunk_overhead;
                    }
                    // OS placement: collisions + migrations
                    dur *= interference.unpinned_factor(total_threads, cost.machine.cores, &mut rng);
                    dur += interference.migration_stall_us(&mut rng);
                    let (stretch, token) = bw.admit(cost.bw_demand(kind, self.intra_op));
                    dur *= stretch;
                    metrics.queue_wait_us += start - ready_at[node as usize];
                    metrics.executor_busy_us[e] += dur;
                    records.push(OpRecord { node, executor: e as u32, start_us: start, end_us: start + dur });
                    q.schedule(start + dur, Ev::Done { node, exec: e as u32, bw_token: token });
                }
            };
        }

        for s in deps.sources() {
            ready.push(s);
        }
        dispatch!(0.0);
        let mut makespan = 0.0f64;
        while let Some((t, ev)) = q.pop() {
            makespan = makespan.max(t);
            match ev {
                Ev::Done { node, exec, bw_token } => {
                    idle.set_idle(exec as usize);
                    bw.release(bw_token);
                    deps.complete(graph, node, |n| {
                        ready_at[n as usize] = t;
                        ready.push(n);
                    });
                }
            }
            dispatch!(t);
        }
        assert!(deps.is_done());
        let result = RunResult { makespan_us: makespan, records, metrics };
        debug_assert!(result.validate(graph).is_ok());
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::GraphiEngine;
    use crate::models::{self, ModelKind, ModelSize};

    #[test]
    fn schedule_valid() {
        let g = models::build(ModelKind::GoogleNet, ModelSize::Small);
        let r = TensorFlowLikeEngine::new(4, 16).run(&g, &SimEnv::knl(3));
        r.validate(&g).unwrap();
    }

    #[test]
    fn fig5_graphi_beats_tensorflow_on_lstm() {
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let env = SimEnv::knl(11);
        let tf = TensorFlowLikeEngine::tuned_for(12, 64).run(&g, &env).makespan_us;
        let graphi = GraphiEngine::new(16, 4).run(&g, &env).makespan_us;
        let speedup = tf / graphi;
        assert!(
            speedup > 1.5,
            "Graphi speedup over TF {speedup:.2}; paper reports 2.1–9.5×"
        );
    }

    #[test]
    fn elementwise_chunking_hurts_lstm_more_than_googlenet() {
        // §7.2: Eigen's chunked job queue hurts nets dense in small
        // element-wise ops (LSTM) most. Compare the queue-contention share
        // of total executor time (the conv-primitive gap is a separate
        // effect, tested via duration_us_mkl).
        let env = SimEnv::knl(5);
        let lstm = models::build(ModelKind::Lstm, ModelSize::Small);
        let goog = models::build(ModelKind::GoogleNet, ModelSize::Small);
        let contention_share = |g: &crate::graph::Graph| {
            let tf = TensorFlowLikeEngine::new(4, 16).run(g, &env);
            let busy: f64 = tf.metrics.executor_busy_us.iter().sum();
            tf.metrics.contention_us / busy
        };
        let lstm_share = contention_share(&lstm);
        let goog_share = contention_share(&goog);
        assert!(
            lstm_share > goog_share,
            "LSTM contention share {lstm_share:.4} should exceed GoogleNet's {goog_share:.4}"
        );
    }
}
