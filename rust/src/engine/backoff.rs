//! Adaptive idle backoff for executor threads: spin → yield → park.
//!
//! PR 3's decentralized executors idled with spin+yield forever. On a
//! fully-loaded manycore part that is exactly the §3 failure mode the
//! paper's disjoint-core mapping exists to avoid: an idle executor's spin
//! loop burns the core (and the shared tile resources) that a *busy*
//! executor's op team needs. Liu et al. (arXiv:1810.08955) measure the
//! same effect as over-threading at high op rates. This module replaces
//! the idle loop with a three-stage state machine:
//!
//! 1. **Spin** for a short burst ([`Backoff::DEFAULT_SPIN_LIMIT`]
//!    iterations) — the common case where a successor batch lands within
//!    a few hundred cycles; parking here would add wake-up latency to the
//!    critical path.
//! 2. **Yield** for a few timeslices — covers the oversubscribed-host case
//!    (1-core CI) where the producer needs our core to make progress.
//! 3. **Park** on an [`EventCounter`] — the executor sleeps on a condvar
//!    and stops burning the core entirely. Producers call
//!    [`EventCounter::notify`] after every deque/ring push, which wakes
//!    parked executors.
//!
//! # The lost-wakeup race, and why [`EventCounter`] closes it
//!
//! The classic bug: executor scans every deque, finds them empty, and
//! parks — but a push landed *between* the scan and the park, and its
//! wakeup fired while nobody was asleep. The executor then sleeps on work
//! that already exists.
//!
//! The counter is a Vyukov-style **eventcount**, built so the busy path
//! stays almost free:
//!
//! * the **producer** publishes work first, then calls `notify`, which is
//!   a `SeqCst` fence plus one load of the waiter count — it pays the
//!   epoch RMW and the condvar broadcast only when some consumer is
//!   inside its prepare→park window;
//! * the **consumer**, once its backoff reaches the park stage, calls
//!   [`EventCounter::prepare`] (register as a waiter, fence, observe the
//!   epoch), **re-scans for work**, and only then either
//!   [`cancel`](EventCounter::cancel)s (work appeared, or shutting down)
//!   or [`park`](EventCounter::park)s with the observed epoch; `park`
//!   re-checks the epoch under the mutex and refuses to sleep if it
//!   moved.
//!
//! Why no wakeup can be lost: a push either happens before the consumer's
//! registered re-scan — the two `SeqCst` fences (producer: after the
//! push, before the waiter-count load; consumer: after registration,
//! before the re-scan) forbid the store-buffer interleaving, so the
//! re-scan *sees the item* — or the producer's waiter-count load sees the
//! registration, bumps the epoch and broadcasts under the mutex, so the
//! consumer's pre-sleep epoch check (same mutex) catches it. A bounded
//! `wait_timeout` backstops the analysis anyway: even a bug here degrades
//! to a periodic poll, never a hang — which is what the stress harness's
//! watchdog (`tests/stress_threaded.rs`) asserts.

use std::sync::atomic::{fence, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// A Vyukov-style eventcount: epoch + waiter count + condvar — the
/// wake-up channel between executors that produce work and executors
/// that idle. See the module docs for the protocol and its proof sketch.
#[derive(Debug, Default)]
pub struct EventCounter {
    epoch: AtomicU64,
    /// Threads inside the prepare→park/cancel window.
    waiters: AtomicUsize,
    lock: Mutex<()>,
    cv: Condvar,
}

impl EventCounter {
    pub fn new() -> EventCounter {
        EventCounter::default()
    }

    /// The current epoch (tests/stats; consumers get theirs from
    /// [`prepare`](Self::prepare)).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    /// Threads currently inside the prepare→park/cancel window (racy;
    /// used by tests and stats).
    pub fn waiters(&self) -> usize {
        self.waiters.load(Ordering::SeqCst)
    }

    /// Producer side: publish that new work exists. On the busy path
    /// (nobody preparing to park) this is a fence plus one relaxed-ish
    /// load — no shared-line RMW, so completing executors don't hammer one
    /// cache line (the contention this PR series exists to remove). Only
    /// when a consumer is inside its prepare→park window does it pay the
    /// epoch bump and the broadcast.
    pub fn notify(&self) {
        // orders the caller's work-publishing stores before the waiter
        // check (producer half of the store-buffer litmus; the consumer
        // half lives in `prepare`)
        fence(Ordering::SeqCst);
        if self.waiters.load(Ordering::SeqCst) > 0 {
            self.epoch.fetch_add(1, Ordering::SeqCst);
            let _guard = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }

    /// Consumer side, step 1: register as a waiter and observe the epoch.
    /// The caller MUST re-scan for work after this and then call exactly
    /// one of [`park`](Self::park) (nothing found) or
    /// [`cancel`](Self::cancel) (found work / shutting down) — that
    /// registered re-scan is what makes the lost-wakeup race impossible.
    pub fn prepare(&self) -> u64 {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        // orders the registration before the caller's re-scan loads
        // (consumer half of the litmus)
        fence(Ordering::SeqCst);
        self.epoch.load(Ordering::SeqCst)
    }

    /// Consumer side: abandon a [`prepare`](Self::prepare)d park.
    pub fn cancel(&self) {
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Consumer side, step 2: sleep until a notify or `timeout`, unless
    /// the epoch already advanced past `observed` (a notify landed since
    /// `prepare` — returns immediately without sleeping). Consumes the
    /// registration. Returns `true` iff it actually slept.
    pub fn park(&self, observed: u64, timeout: Duration) -> bool {
        let slept = {
            let guard = self.lock.lock().unwrap();
            if self.epoch.load(Ordering::SeqCst) == observed {
                // the mutex is released atomically by wait_timeout, so a
                // broadcast cannot fall between this check and the sleep
                let _unused = self.cv.wait_timeout(guard, timeout).unwrap();
                true
            } else {
                false
            }
        };
        self.waiters.fetch_sub(1, Ordering::SeqCst);
        slept
    }
}

/// What an idle executor should do on its next empty-handed iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackoffStage {
    /// `spin_loop()` — expecting work within cycles.
    Spin,
    /// `yield_now()` — give the producer our timeslice.
    Yield,
    /// Park on the [`EventCounter`] — stop burning the core.
    Park,
}

/// Per-executor idle-backoff state machine: `spin_limit` spins, then
/// `yield_limit` yields, then parks until reset. Acquiring work resets it
/// to the spin stage.
#[derive(Debug, Clone)]
pub struct Backoff {
    attempts: u32,
    spin_limit: u32,
    yield_limit: u32,
}

impl Default for Backoff {
    fn default() -> Self {
        Backoff::new()
    }
}

impl Backoff {
    /// Spin iterations before the first yield. Short: one failed steal
    /// sweep already costs a few hundred cycles, so ~64 sweeps bound the
    /// spin phase to the microsecond scale where parking latency would
    /// hurt the critical path.
    pub const DEFAULT_SPIN_LIMIT: u32 = 64;
    /// Yields before parking.
    pub const DEFAULT_YIELD_LIMIT: u32 = 16;

    pub fn new() -> Backoff {
        Backoff::with_limits(Self::DEFAULT_SPIN_LIMIT, Self::DEFAULT_YIELD_LIMIT)
    }

    pub fn with_limits(spin_limit: u32, yield_limit: u32) -> Backoff {
        Backoff { attempts: 0, spin_limit, yield_limit }
    }

    /// Work was acquired — return to the spin stage.
    pub fn reset(&mut self) {
        self.attempts = 0;
    }

    /// The stage the *next* idle iteration is in, without advancing.
    pub fn stage(&self) -> BackoffStage {
        if self.attempts < self.spin_limit {
            BackoffStage::Spin
        } else if self.attempts < self.spin_limit + self.yield_limit {
            BackoffStage::Yield
        } else {
            BackoffStage::Park
        }
    }

    /// Advance one idle iteration and return the stage it falls in. Park
    /// is sticky: once reached, every further call returns `Park` until
    /// [`reset`](Self::reset).
    pub fn next(&mut self) -> BackoffStage {
        let stage = self.stage();
        if stage != BackoffStage::Park {
            self.attempts += 1;
        }
        stage
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::time::Instant;

    #[test]
    fn state_machine_walks_spin_yield_park_and_resets() {
        let mut b = Backoff::with_limits(3, 2);
        assert_eq!(b.stage(), BackoffStage::Spin);
        for _ in 0..3 {
            assert_eq!(b.next(), BackoffStage::Spin);
        }
        for _ in 0..2 {
            assert_eq!(b.next(), BackoffStage::Yield);
        }
        // park is sticky
        for _ in 0..10 {
            assert_eq!(b.next(), BackoffStage::Park);
        }
        b.reset();
        assert_eq!(b.next(), BackoffStage::Spin);
        // defaults walk the documented limits
        let mut d = Backoff::new();
        let mut spins = 0;
        while d.next() == BackoffStage::Spin {
            spins += 1;
        }
        assert_eq!(spins, Backoff::DEFAULT_SPIN_LIMIT);
        let mut yields = 1; // the call that left Spin was a Yield
        while d.next() == BackoffStage::Yield {
            yields += 1;
        }
        assert_eq!(yields, Backoff::DEFAULT_YIELD_LIMIT);
        assert_eq!(d.stage(), BackoffStage::Park);
    }

    #[test]
    fn park_refuses_to_sleep_when_a_notify_landed_after_prepare() {
        // the lost-wakeup race, replayed deterministically: the "push"
        // (notify) lands between prepare and park — park must return
        // immediately instead of sleeping through the 10 s timeout
        let ec = EventCounter::new();
        let observed = ec.prepare(); // consumer registered, about to re-scan
        ec.notify(); // producer: push + (waiters > 0 ⇒ epoch bump) land here
        let t0 = Instant::now();
        let slept = ec.park(observed, Duration::from_secs(10));
        assert!(!slept, "park slept through a post-prepare notify");
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "park blocked despite a stale epoch observation"
        );
        assert_eq!(ec.waiters(), 0, "registration must be consumed");
    }

    #[test]
    fn notify_without_waiters_is_the_cheap_path() {
        // nobody inside a prepare→park window ⇒ notify must not touch the
        // epoch (no shared-line RMW on the busy path)
        let ec = EventCounter::new();
        for _ in 0..100 {
            ec.notify();
        }
        assert_eq!(ec.epoch(), 0, "epoch bumps only when someone is waiting");
        // …and with a registered waiter it does bump
        let observed = ec.prepare();
        ec.notify();
        assert!(ec.epoch() > observed);
        ec.cancel();
        assert_eq!(ec.waiters(), 0);
    }

    #[test]
    fn cancel_abandons_a_prepared_park() {
        let ec = EventCounter::new();
        let _observed = ec.prepare();
        assert_eq!(ec.waiters(), 1);
        ec.cancel(); // "the re-scan found work"
        assert_eq!(ec.waiters(), 0);
        ec.notify(); // cheap path again
        assert_eq!(ec.epoch(), 0);
    }

    #[test]
    fn notify_wakes_a_parked_thread() {
        let ec = EventCounter::new();
        let woke = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| {
                let observed = ec.prepare();
                // generous timeout: the test passes because the notify
                // arrives (or already voided the observation), not
                // because the timeout expires
                ec.park(observed, Duration::from_secs(30));
                woke.store(true, Ordering::SeqCst);
            });
            // wait until the thread is registered, then notify
            while ec.waiters() == 0 {
                std::thread::yield_now();
            }
            ec.notify();
        });
        assert!(woke.load(Ordering::SeqCst));
    }

    #[test]
    fn park_timeout_is_a_backstop_not_a_hang() {
        let ec = EventCounter::new();
        let observed = ec.prepare();
        let t0 = Instant::now();
        let slept = ec.park(observed, Duration::from_millis(10));
        assert!(slept, "nothing notified, so the park must actually sleep");
        assert!(t0.elapsed() >= Duration::from_millis(5));
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert_eq!(ec.waiters(), 0);
    }

    #[test]
    fn concurrent_notifies_with_a_waiter_stay_monotone() {
        let ec = EventCounter::new();
        let _observed = ec.prepare(); // keep one waiter registered
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        ec.notify();
                    }
                });
            }
        });
        assert_eq!(ec.epoch(), 4000);
        ec.cancel();
        assert_eq!(ec.waiters(), 0);
    }
}
