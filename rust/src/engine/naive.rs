//! Naive parallel baseline: the TensorFlow/MXNet scheduling scheme
//! (§3.1, §4.3) — one shared ready queue, autonomous executors polling it.
//!
//! Used for Table 2: thread interference is *eliminated* (pinned disjoint
//! placement, same primitives), so any gap vs Graphi is attributable to
//! (a) shared-queue polling contention and (b) FIFO-arbitrary ordering
//! instead of critical-path-first.

use crate::cost::Interference;
use crate::graph::{Graph, NodeId};
use crate::sim::topology::PlacementKind;
use crate::sim::{BandwidthArbiter, EventQueue};
use crate::util::rng::Rng;

use super::policies::Policy;
use super::ready::{DepTracker, ReadySet};
use super::scheduler::IdleBitmap;
use super::trace::OpRecord;
use super::{Engine, EngineMetrics, RunResult, SimEnv};

/// Shared-global-queue engine.
#[derive(Debug, Clone)]
pub struct NaiveEngine {
    pub executors: usize,
    pub threads_per: usize,
    /// Pinned placement (Table 2's interference-free setting) or OS-managed.
    pub placement: PlacementKind,
}

impl NaiveEngine {
    pub fn new(executors: usize, threads_per: usize) -> NaiveEngine {
        NaiveEngine { executors, threads_per, placement: PlacementKind::PinnedDisjoint }
    }
}

enum Ev {
    Done { node: NodeId, exec: u32, bw_token: u64 },
}

impl Engine for NaiveEngine {
    fn name(&self) -> String {
        format!("naive-{}x{}", self.executors, self.threads_per)
    }

    fn run(&self, graph: &Graph, env: &SimEnv) -> RunResult {
        let cost = &env.cost;
        let interference = Interference::new(cost.cal.clone());
        let mut rng: Rng = env.rng();
        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut deps = DepTracker::new(graph);
        // FIFO: "whenever an executor is available, it randomly picks a
        // ready operation" — arbitrary topological order (FIFO never
        // consults levels, so none are allocated)
        let mut ready = ReadySet::new(Policy::Fifo, Vec::<f64>::new(), env.seed);
        let mut idle = IdleBitmap::new(self.executors);
        let mut bw = BandwidthArbiter::new(cost.machine.mcdram_bw);
        let mut records = Vec::with_capacity(graph.len());
        let mut metrics = EngineMetrics {
            executor_busy_us: vec![0.0; self.executors],
            ..Default::default()
        };
        let mut ready_at = vec![0.0f64; graph.len()];

        let unpinned = self.placement == PlacementKind::OsManaged;
        let total_threads = self.executors * self.threads_per;
        // The shared MPMC queue serializes dequeues: only one CAS wins at a
        // time, and each successful dequeue takes longer when more idle
        // executors are hammering the same cache line (§3.1, §4.3). Model
        // it as a serial resource with contention-dependent service time.
        let mut queue_free_us = 0.0f64;

        macro_rules! dispatch {
            ($now:expr) => {
                while !ready.is_empty() && idle.any_idle() {
                    let e = idle.first_idle().unwrap();
                    // all currently idle executors are spinning on the queue
                    let pollers = idle.count_idle();
                    let dq = interference.shared_queue_dequeue_us(pollers)
                        + interference.wake_latency_us();
                    let dq_start = queue_free_us.max($now);
                    queue_free_us = dq_start + dq;
                    metrics.contention_us += queue_free_us - $now - cost.cal.queue_base_us;
                    metrics.dispatches += 1;
                    idle.set_busy(e);
                    let node = ready.pop().unwrap();
                    let kind = &graph.node(node).kind;
                    let start = queue_free_us;
                    let mut dur = cost.duration_us(kind, self.threads_per) * interference.noise(&mut rng);
                    if unpinned {
                        dur *= interference.unpinned_factor(total_threads, cost.machine.cores, &mut rng);
                        dur += interference.migration_stall_us(&mut rng);
                    }
                    let (stretch, token) = bw.admit(cost.bw_demand(kind, self.threads_per));
                    dur *= stretch;
                    metrics.queue_wait_us += start - ready_at[node as usize];
                    metrics.executor_busy_us[e] += dur;
                    records.push(OpRecord { node, executor: e as u32, start_us: start, end_us: start + dur });
                    q.schedule(start + dur, Ev::Done { node, exec: e as u32, bw_token: token });
                }
            };
        }

        for s in deps.sources() {
            ready.push(s);
        }
        dispatch!(0.0);
        let mut makespan = 0.0f64;
        while let Some((t, ev)) = q.pop() {
            makespan = makespan.max(t);
            match ev {
                Ev::Done { node, exec, bw_token } => {
                    idle.set_idle(exec as usize);
                    bw.release(bw_token);
                    deps.complete(graph, node, |n| {
                        ready_at[n as usize] = t;
                        ready.push(n);
                    });
                }
            }
            dispatch!(t);
        }
        assert!(deps.is_done());
        let result = RunResult { makespan_us: makespan, records, metrics };
        debug_assert!(result.validate(graph).is_ok());
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::GraphiEngine;
    use crate::models::{self, ModelKind, ModelSize};

    #[test]
    fn schedule_valid() {
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        let r = NaiveEngine::new(8, 8).run(&g, &SimEnv::knl_deterministic());
        r.validate(&g).unwrap();
    }

    #[test]
    fn table2_graphi_beats_naive_on_lstm() {
        // Table 2: Graphi/naive relative time 0.81–0.94 on medium nets;
        // use small LSTM here for test speed — the shape must hold.
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let env = SimEnv::knl_deterministic();
        let naive = NaiveEngine::new(16, 4).run(&g, &env).makespan_us;
        let graphi = GraphiEngine::new(16, 4).run(&g, &env).makespan_us;
        let rel = graphi / naive;
        assert!(
            rel < 0.99,
            "graphi/naive = {rel:.3}; scheduler must win (paper: 0.81–0.94)"
        );
    }

    #[test]
    fn contention_grows_with_executor_count() {
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let env = SimEnv::knl_deterministic();
        let few = NaiveEngine::new(2, 32).run(&g, &env);
        let many = NaiveEngine::new(32, 2).run(&g, &env);
        assert!(
            many.metrics.contention_us > 4.0 * few.metrics.contention_us,
            "contention: 32 exec {} vs 2 exec {}",
            many.metrics.contention_us,
            few.metrics.contention_us
        );
    }
}
