//! Heterogeneous executor-class engine — §6's first rejected optimization,
//! implemented for real.
//!
//! > "We classified the operations into multiple classes (e.g. 3)
//! > according to how well they scale, and made the scheduler preferably
//! > assign an operation to an executor of corresponding thread team size.
//! > This technique indeed reduced the total CPU time of all the threads.
//! > However, the makespan of the whole graph execution did not improve
//! > … different executor sizes could cause work straggling when some big
//! > operations are scheduled to run on the executors with a small team."
//!
//! The fleet is a list of `(executors, threads)` classes. Each op's
//! preferred class is the largest team it can still use at ≥50 % parallel
//! efficiency; the scheduler dispatches to an idle executor of that class, and
//! (work-conservingly) falls back to any idle executor otherwise — which
//! is exactly where the paper's straggling comes from: a GEMM that lands
//! on a 2-thread executor holds the critical path hostage.
//!
//! The bench compares total CPU time (improves) against makespan (does
//! not) — both paper claims.

use crate::graph::{levels, Graph, NodeId};
use crate::sim::{BandwidthArbiter, EventQueue};

use super::policies::Policy;
use super::ready::{DepTracker, ReadySet};
use super::trace::{OpRecord, LIGHTWEIGHT_EXECUTOR};
use super::{Engine, EngineMetrics, RunResult, SimEnv};

/// A fleet of executor classes with different team sizes.
#[derive(Debug, Clone)]
pub struct HeterogeneousEngine {
    /// `(executors, threads_per)` per class.
    pub classes: Vec<(usize, usize)>,
    /// Work-conserving fallback: if the preferred class is busy, take any
    /// idle executor (the paper's behaviour). With `false`, ops wait for
    /// their class — even worse straggling.
    pub work_conserving: bool,
}

impl HeterogeneousEngine {
    /// The paper's "e.g. 3 classes" shape over 64 worker cores:
    /// 2×16 (big GEMMs) + 4×4 (medium) + 16×1 (small element-wise).
    pub fn paper_default() -> HeterogeneousEngine {
        HeterogeneousEngine {
            classes: vec![(2, 16), (4, 4), (16, 1)],
            work_conserving: true,
        }
    }

    fn total_executors(&self) -> usize {
        self.classes.iter().map(|&(e, _)| e).sum()
    }

    /// Executor index → team size.
    fn teams(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.total_executors());
        for &(e, t) in &self.classes {
            out.extend(std::iter::repeat_n(t, e));
        }
        out
    }
}

enum Ev {
    Done { node: NodeId, exec: usize, bw_token: u64 },
    DoneLw { node: NodeId },
}

impl Engine for HeterogeneousEngine {
    fn name(&self) -> String {
        let classes: Vec<String> =
            self.classes.iter().map(|&(e, t)| format!("{e}x{t}")).collect();
        format!("heterogeneous-{}", classes.join("+"))
    }

    fn run(&self, graph: &Graph, env: &SimEnv) -> RunResult {
        let cost = &env.cost;
        let interference = env.interference();
        let mut rng = env.rng();
        let teams = self.teams();
        let n_exec = teams.len();

        // preferred class per node — §6: "according to how well they
        // scale": the largest class team the op still uses with ≥50 %
        // parallel efficiency; poorly-scaling ops get small teams.
        let mut class_teams: Vec<usize> = self.classes.iter().map(|&(_, t)| t).collect();
        class_teams.sort_unstable();
        let preferred_team: Vec<usize> = graph
            .nodes()
            .iter()
            .map(|n| {
                class_teams
                    .iter()
                    .rev()
                    .find(|&&t| cost.speedup(&n.kind, t) / t as f64 >= 0.5)
                    .copied()
                    .unwrap_or(class_teams[0])
            })
            .collect();
        // per-node duration per team size (cached per distinct team)
        let mut distinct: Vec<usize> = teams.clone();
        distinct.sort_unstable();
        distinct.dedup();
        let dur_by_team: std::collections::BTreeMap<usize, Vec<f64>> = distinct
            .iter()
            .map(|&t| {
                (t, graph.nodes().iter().map(|n| cost.duration_us(&n.kind, t)).collect())
            })
            .collect();
        // levels from the preferred-class durations
        let pref_durations: Vec<f64> = (0..graph.len())
            .map(|v| dur_by_team[&preferred_team[v]][v])
            .collect();
        let level_values = levels(graph, &pref_durations);

        let mut deps = DepTracker::new(graph);
        let mut ready = ReadySet::new(Policy::CriticalPathFirst, level_values, env.seed);
        let mut idle: Vec<bool> = vec![true; n_exec];
        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut bw = BandwidthArbiter::new(cost.machine.mcdram_bw);
        let mut records = Vec::with_capacity(graph.len());
        let mut metrics = EngineMetrics {
            executor_busy_us: vec![0.0; n_exec],
            ..Default::default()
        };
        let mut sched_free = 0.0f64;
        let mut lw_free = 0.0f64;
        // ops that chose to wait for their class (non-work-conserving)
        let mut parked: Vec<NodeId> = Vec::new();

        macro_rules! dispatch {
            ($now:expr) => {
                // re-offer parked ops first
                let mut offer: Vec<NodeId> = std::mem::take(&mut parked);
                while let Some(node) = if !offer.is_empty() { offer.pop() } else { ready.pop() } {
                    let kind = &graph.node(node).kind;
                    if kind.is_tiny() {
                        let start = lw_free.max($now);
                        let dur = cost.cal.tiny_op_us * interference.noise(&mut rng);
                        lw_free = start + dur;
                        metrics.lightweight_ops += 1;
                        records.push(OpRecord {
                            node,
                            executor: LIGHTWEIGHT_EXECUTOR,
                            start_us: start,
                            end_us: start + dur,
                        });
                        q.schedule(start + dur, Ev::DoneLw { node });
                        continue;
                    }
                    // preferred-class idle executor, else any idle
                    let want = preferred_team[node as usize];
                    let slot = (0..n_exec)
                        .find(|&e| idle[e] && teams[e] == want)
                        .or_else(|| {
                            if self.work_conserving {
                                // nearest-team idle executor — "preferably
                                // assign", not "strictly assign"
                                (0..n_exec).filter(|&e| idle[e]).min_by_key(|&e| {
                                    (teams[e] as i64 - want as i64).unsigned_abs()
                                })
                            } else {
                                None
                            }
                        });
                    let Some(e) = slot else {
                        if self.work_conserving {
                            // no executor at all: push back and stop
                            ready.push(node);
                        } else {
                            parked.push(node);
                            continue; // maybe another ready op fits a free class
                        }
                        break;
                    };
                    idle[e] = false;
                    sched_free = sched_free.max($now) + interference.graphi_dispatch_us();
                    metrics.dispatches += 1;
                    let start = sched_free;
                    let base = dur_by_team[&teams[e]][node as usize];
                    let mut dur = base * interference.noise(&mut rng);
                    let (stretch, token) = bw.admit(kind.bytes() / (base * 1e-6).max(1e-12));
                    dur *= stretch;
                    metrics.executor_busy_us[e] += dur;
                    records.push(OpRecord { node, executor: e as u32, start_us: start, end_us: start + dur });
                    q.schedule(start + dur, Ev::Done { node, exec: e, bw_token: token });
                }
                parked.extend(offer);
            };
        }

        for s in deps.sources() {
            ready.push(s);
        }
        dispatch!(0.0);
        let mut makespan = 0.0f64;
        while let Some((t, ev)) = q.pop() {
            makespan = makespan.max(t);
            match ev {
                Ev::Done { node, exec, bw_token } => {
                    idle[exec] = true;
                    bw.release(bw_token);
                    deps.complete(graph, node, |n| ready.push(n));
                }
                Ev::DoneLw { node } => {
                    deps.complete(graph, node, |n| ready.push(n));
                }
            }
            dispatch!(t);
        }
        assert!(deps.is_done(), "heterogeneous engine drained with unexecuted ops");
        let result = RunResult { makespan_us: makespan, records, metrics };
        debug_assert!(result.validate(graph).is_ok(), "{:?}", result.validate(graph));
        result
    }
}

/// Total thread-seconds consumed (CPU time): Σ duration × team size.
/// §6's claim is that heterogeneous classes reduce this while *not*
/// improving makespan.
pub fn cpu_time_us(result: &RunResult, teams: &[usize]) -> f64 {
    result
        .records
        .iter()
        .map(|r| {
            if r.executor == u32::MAX {
                r.duration_us() // light-weight executor: 1 thread
            } else {
                r.duration_us() * teams[r.executor as usize] as f64
            }
        })
        .sum()
}

impl HeterogeneousEngine {
    /// Public access to the executor→team mapping (for `cpu_time_us`).
    pub fn team_map(&self) -> Vec<usize> {
        self.teams()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::GraphiEngine;
    use crate::models::{self, ModelKind, ModelSize};

    #[test]
    fn produces_valid_schedule() {
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let env = SimEnv::knl_deterministic();
        let engine = HeterogeneousEngine::paper_default();
        let r = engine.run(&g, &env);
        r.validate(&g).unwrap();
        assert_eq!(r.records.len(), g.len());
    }

    #[test]
    fn paper_finding_cpu_time_down_makespan_not_better() {
        // §6: heterogeneous classes reduce total CPU time but do not
        // improve the makespan vs symmetric executors on LSTM.
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let env = SimEnv::knl_deterministic();
        let hetero = HeterogeneousEngine::paper_default();
        let hr = hetero.run(&g, &env);
        // symmetric fleet with comparable core count (2·16+4·4+16·1 = 64)
        let symmetric = GraphiEngine::new(8, 8);
        let sr = symmetric.run(&g, &env);
        let hetero_cpu = cpu_time_us(&hr, &hetero.team_map());
        let sym_cpu = cpu_time_us(&sr, &vec![8; 8]);
        assert!(
            hetero_cpu < sym_cpu,
            "hetero CPU time {hetero_cpu:.0} should beat symmetric {sym_cpu:.0}"
        );
        assert!(
            hr.makespan_us > sr.makespan_us * 0.95,
            "makespan must NOT meaningfully improve: hetero {} vs symmetric {}",
            hr.makespan_us,
            sr.makespan_us
        );
    }

    #[test]
    fn non_work_conserving_is_worse() {
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        let env = SimEnv::knl_deterministic();
        let wc = HeterogeneousEngine::paper_default().run(&g, &env).makespan_us;
        let strict = HeterogeneousEngine { work_conserving: false, ..HeterogeneousEngine::paper_default() }
            .run(&g, &env)
            .makespan_us;
        assert!(strict >= wc, "strict classes {strict} vs work-conserving {wc}");
    }

    #[test]
    fn team_map_shape() {
        let e = HeterogeneousEngine::paper_default();
        let teams = e.team_map();
        assert_eq!(teams.len(), 22);
        assert_eq!(teams[0], 16);
        assert_eq!(teams[21], 1);
    }
}
