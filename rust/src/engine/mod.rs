//! The Graphi execution engine and its baselines (§4–§5 of the paper).
//!
//! Components:
//!
//! * [`ring`]      — the lock-free SPSC ring buffer backing per-executor
//!   operation buffers (§5.2, MuQSS-inspired)
//! * [`mpsc`]      — the bounded MPSC completion queue that funnels
//!   executor→scheduler completions through one structure instead of a
//!   per-executor scan (threaded engine)
//! * [`ready`]     — dependency tracking + the ready-operation set under a
//!   pluggable ordering [`policies::Policy`]
//! * [`scheduler`] — the centralized scheduler's decision core: idle-executor
//!   bitmap (bit-scan), level max-heap, per-executor push
//! * [`profiler`]  — §4.2: symmetric-config search + per-op duration
//!   estimation over the first iterations
//! * [`autotune`]  — successive-halving search over the same candidate
//!   space, feeding duration estimates back into the scheduler's levels
//!   and persisting the result as a tuning artifact
//! * engines (all implement [`Engine`]):
//!   - [`graphi`]          — the paper's system (centralized CP-first)
//!   - [`sequential`]      — one executor, topological order
//!   - [`naive`]           — TF/MXNet-style shared global ready queue
//!   - [`tensorflow_like`] — adds unpinned threads, oversubscribed pools,
//!     Eigen-chunked element-wise ops, MKL conv (the Fig 5 baseline)
//! * [`trace`]     — per-op execution records, Chrome trace export,
//!   wavefront analysis (§7.4's cuDNN-diagonal observation)
//!
//! Engines execute on the discrete-event substrate in [`crate::sim`];
//! the threaded (real-parallelism, PJRT-backed) engine lives in
//! [`crate::runtime::threaded`].

pub mod autotune;
pub mod backoff;
pub mod dynamic;
pub mod graphi;
pub mod heterogeneous;
pub mod mpsc;
pub mod naive;
pub mod policies;
pub mod profiler;
pub mod ready;
pub mod ring;
pub mod scheduler;
pub mod sequential;
pub mod tensorflow_like;
pub mod trace;
pub mod worksteal;

pub use autotune::{AutotuneReport, AutotuneRound, Autotuner};
pub use backoff::{Backoff, BackoffStage, EventCounter};
pub use dynamic::DynamicFleetEngine;
pub use graphi::{GraphiEngine, SessionSimResult, SimArrival, SimFault, SimSessionOutcome};
pub use heterogeneous::HeterogeneousEngine;
pub use naive::NaiveEngine;
pub use policies::Policy;
pub use profiler::{ProfileReport, Profiler};
pub use sequential::SequentialEngine;
pub use tensorflow_like::TensorFlowLikeEngine;
pub use trace::{
    export_chrome_trace, validate_chrome_trace, ChromeTraceBuilder, ChromeTraceStats, FleetEvent,
    FleetEventKind, OpRecord, SessionTraceExport, Trace,
};
pub use worksteal::{Acquire, DomainMap, Steal, WorkStealDeque};

use crate::cost::{Calibration, CostModel, Interference};
use crate::graph::op::OpClass;
use crate::graph::Graph;
use crate::util::rng::Rng;

/// How completions turn into new dispatches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// §4/§5 (PR-1) architecture: every completion round-trips through the
    /// central scheduler — completion queue → `DepTracker` → ready-heap →
    /// per-executor buffer.
    Centralized,
    /// Executor-side successor resolution over the CSR layout
    /// ([`crate::graph::AtomicDepTracker`]) plus CP-aware work stealing
    /// ([`worksteal`]); the coordinator only handles startup, quiescence
    /// and trace collection.
    Decentralized,
}

impl DispatchMode {
    pub const ALL: [DispatchMode; 2] = [DispatchMode::Centralized, DispatchMode::Decentralized];

    pub fn name(self) -> &'static str {
        match self {
            DispatchMode::Centralized => "centralized",
            DispatchMode::Decentralized => "decentralized",
        }
    }

    pub fn parse(s: &str) -> Option<DispatchMode> {
        match s.to_ascii_lowercase().as_str() {
            "centralized" | "central" => Some(DispatchMode::Centralized),
            "decentralized" | "decentral" => Some(DispatchMode::Decentralized),
            _ => None,
        }
    }

    /// The other architecture (the per-phase search's flip move).
    pub fn other(self) -> DispatchMode {
        match self {
            DispatchMode::Centralized => DispatchMode::Decentralized,
            DispatchMode::Decentralized => DispatchMode::Centralized,
        }
    }

    /// Three-way dispatch-mode precedence, pinned in one place so it
    /// cannot drift as sources multiply: an **explicit `--dispatch` flag**
    /// beats a **tuning artifact's winner**, which beats a **config-file
    /// `engine.dispatch`**; `None` everywhere leaves the engine default
    /// (centralized for the simulator driver). Phase plans follow the
    /// same rule: an explicit flag pins a *uniform* mode and therefore
    /// drops any artifact phase plan.
    pub fn resolve(
        flag: Option<DispatchMode>,
        artifact: Option<DispatchMode>,
        config: Option<DispatchMode>,
    ) -> Option<DispatchMode> {
        flag.or(artifact).or(config)
    }
}

/// A per-phase dispatch assignment: the graph is split into **width
/// phases** ([`crate::graph::levels::width_phases`] at `threshold`) and
/// each phase runs under its own [`DispatchMode`], with a barrier at every
/// phase boundary (safe because a node's predecessors always live in the
/// same or an earlier phase). Liu et al. (arXiv:1810.08955) observed that
/// the right concurrency setting varies *within* one graph's phases —
/// narrow chains want the centralized scheduler's light-weight lane, wide
/// fan-outs want executor-side resolution + stealing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhasePlan {
    /// The width threshold the phases were derived with; apply-time phase
    /// derivation must use the same value or the plan does not line up.
    pub threshold: usize,
    /// One mode per phase, in phase order.
    pub modes: Vec<DispatchMode>,
}

impl PhasePlan {
    /// A plan running every phase under one mode (the baseline the
    /// autotuner's flip search starts from).
    pub fn uniform(threshold: usize, mode: DispatchMode, phases: usize) -> PhasePlan {
        PhasePlan { threshold, modes: vec![mode; phases] }
    }

    /// Does this plan line up with `graph`'s phase structure?
    pub fn matches(&self, graph: &Graph) -> bool {
        !self.modes.is_empty()
            && crate::graph::levels::width_phases(graph, self.threshold).len() == self.modes.len()
    }

    /// Number of phase boundaries where the mode actually changes.
    pub fn mode_switches(&self) -> u64 {
        self.modes.windows(2).filter(|w| w[0] != w[1]).count() as u64
    }

    /// Compact human-readable form, e.g. `c|d|c` (threshold 4).
    pub fn render(&self) -> String {
        let tags: Vec<&str> = self
            .modes
            .iter()
            .map(|m| match m {
                DispatchMode::Centralized => "c",
                DispatchMode::Decentralized => "d",
            })
            .collect();
        format!("{} (width threshold {})", tags.join("|"), self.threshold)
    }
}

/// A per-op-class **moldable width** assignment: ops of class `c` request
/// a gang of `width_for(c)` executors (the popping executor plus
/// `width − 1` recruited peers), partitioning the op body across the gang.
/// Widths are chosen per *class*, not per node — the classes are exactly
/// the Fig-2 saturation curves, so one width per curve is the natural
/// search granularity (Wang et al., arXiv:1908.04705, tune per-op-type
/// intra-op parallelism the same way). `uniform(1)` is the identity plan:
/// every packed entry stays bit-compatible with the width-free runtime.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WidthPlan {
    /// Width per [`OpClass`], indexed by [`OpClass::index`]. Each in
    /// `1..=`[`ready::MAX_WIDTH`]; the runtime additionally clamps to the
    /// fleet's executor count and forces Tiny ops to 1.
    widths: [u32; OpClass::COUNT],
}

impl WidthPlan {
    /// The identity plan: every class at width `w` (usually 1).
    pub fn uniform(w: u32) -> WidthPlan {
        debug_assert!(w >= 1 && w <= ready::MAX_WIDTH);
        WidthPlan { widths: [w; OpClass::COUNT] }
    }

    /// The gang width requested for ops of `class`.
    pub fn width_for(&self, class: OpClass) -> u32 {
        self.widths[class.index()]
    }

    /// Set the width for one class (clamped to `1..=MAX_WIDTH`).
    pub fn set(&mut self, class: OpClass, w: u32) {
        self.widths[class.index()] = w.clamp(1, ready::MAX_WIDTH);
    }

    /// Is this the identity (`w = 1` everywhere) plan?
    pub fn is_uniform_one(&self) -> bool {
        self.widths.iter().all(|&w| w == 1)
    }

    /// The largest width any class requests.
    pub fn max_width(&self) -> u32 {
        self.widths.iter().copied().max().unwrap_or(1)
    }

    /// Compact human-readable form, e.g. `gemm:4 conv:2 elementwise:1
    /// memory:1 tiny:1`.
    pub fn render(&self) -> String {
        OpClass::ALL
            .iter()
            .map(|c| format!("{}:{}", c.name(), self.width_for(*c)))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Parse a CLI-style spec like `gemm=4,conv=2` (unlisted classes stay
    /// at width 1). Accepts `:` or `=` as the separator. Rejects unknown
    /// class names and widths outside `1..=MAX_WIDTH`.
    pub fn parse(text: &str) -> Result<WidthPlan, String> {
        let mut plan = WidthPlan::uniform(1);
        for part in text.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (name, value) = part
                .split_once('=')
                .or_else(|| part.split_once(':'))
                .ok_or_else(|| format!("bad width entry `{part}` (want class=width)"))?;
            let class = OpClass::ALL
                .into_iter()
                .find(|c| c.name() == name.trim())
                .ok_or_else(|| {
                    format!(
                        "unknown op class `{}` (have: {})",
                        name.trim(),
                        OpClass::ALL.map(|c| c.name()).join(", ")
                    )
                })?;
            let w: u32 = value
                .trim()
                .parse()
                .ok()
                .filter(|&w| (1..=ready::MAX_WIDTH).contains(&w))
                .ok_or_else(|| {
                    format!(
                        "width `{}` for `{}` outside 1..={}",
                        value.trim(),
                        class.name(),
                        ready::MAX_WIDTH
                    )
                })?;
            plan.set(class, w);
        }
        Ok(plan)
    }
}

impl Default for WidthPlan {
    fn default() -> WidthPlan {
        WidthPlan::uniform(1)
    }
}

/// Shared environment for a simulated run.
#[derive(Debug, Clone)]
pub struct SimEnv {
    pub cost: CostModel,
    pub seed: u64,
}

impl SimEnv {
    pub fn knl(seed: u64) -> SimEnv {
        SimEnv { cost: CostModel::knl(), seed }
    }

    /// Noise-free environment for deterministic tests.
    pub fn knl_deterministic() -> SimEnv {
        SimEnv { cost: CostModel::knl_deterministic(), seed: 0 }
    }

    pub fn interference(&self) -> Interference {
        Interference::new(self.cost.cal.clone())
    }

    pub fn rng(&self) -> Rng {
        Rng::new(self.seed)
    }

    pub fn calibration(&self) -> &Calibration {
        &self.cost.cal
    }
}

/// Aggregate engine metrics for one graph execution.
#[derive(Debug, Clone, Default)]
pub struct EngineMetrics {
    /// Number of scheduler dispatch decisions.
    pub dispatches: u64,
    /// Total time ops spent waiting ready-but-unscheduled, µs.
    pub queue_wait_us: f64,
    /// Total scheduler busy time, µs.
    pub scheduler_busy_us: f64,
    /// Total time spent in queue-contention overhead, µs.
    pub contention_us: f64,
    /// Per-executor busy time, µs.
    pub executor_busy_us: Vec<f64>,
    /// Ops routed to the light-weight executor.
    pub lightweight_ops: u64,
    /// Decentralized dispatch: ops acquired by stealing (0 otherwise).
    pub steals: u64,
    /// Of `steals`, how many crossed a NUMA-domain boundary (and paid the
    /// `steal_cross_domain_us` surcharge).
    pub steals_cross_domain: u64,
    /// Phased runs: phase boundaries where the dispatch mode changed.
    pub mode_switches: u64,
    /// Moldable gangs formed: ops that ran at effective width > 1.
    pub gangs_formed: u64,
    /// Executors recruited into gangs (sum of `width − 1` over formed
    /// gangs) — each recruit cost `gang_recruit_us` of scheduler time.
    pub gang_recruits: u64,
}

impl EngineMetrics {
    /// Mean executor utilization over the makespan.
    pub fn utilization(&self, makespan_us: f64) -> f64 {
        if self.executor_busy_us.is_empty() || makespan_us <= 0.0 {
            return 0.0;
        }
        let busy: f64 = self.executor_busy_us.iter().sum();
        busy / (makespan_us * self.executor_busy_us.len() as f64)
    }
}

/// Result of one graph execution.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub makespan_us: f64,
    pub records: Vec<OpRecord>,
    pub metrics: EngineMetrics,
}

impl RunResult {
    /// Self-check: records must respect graph dependencies and not overlap
    /// per executor. Engines call this in debug builds; tests call it
    /// directly.
    pub fn validate(&self, graph: &Graph) -> Result<(), String> {
        trace::validate_records(graph, &self.records, self.makespan_us)
    }
}

/// A computation-graph execution engine.
pub trait Engine {
    /// Descriptive name for reports.
    fn name(&self) -> String;

    /// Execute the graph once, returning the simulated result.
    fn run(&self, graph: &Graph, env: &SimEnv) -> RunResult;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_mode_roundtrip_and_aliases() {
        for m in DispatchMode::ALL {
            assert_eq!(DispatchMode::parse(m.name()), Some(m));
            assert_eq!(m.other().other(), m);
            assert_ne!(m.other(), m);
        }
        assert_eq!(DispatchMode::parse("central"), Some(DispatchMode::Centralized));
        assert_eq!(DispatchMode::parse("DECENTRAL"), Some(DispatchMode::Decentralized));
        assert_eq!(DispatchMode::parse("psychic"), None);
    }

    #[test]
    fn dispatch_precedence_is_flag_artifact_config_default() {
        use DispatchMode::{Centralized as C, Decentralized as D};
        // the satellite's pinned order: flag > artifact > config > default
        assert_eq!(DispatchMode::resolve(Some(C), Some(D), Some(D)), Some(C));
        assert_eq!(DispatchMode::resolve(None, Some(D), Some(C)), Some(D));
        assert_eq!(DispatchMode::resolve(None, None, Some(D)), Some(D));
        assert_eq!(DispatchMode::resolve(None, None, None), None, "None = engine default");
        // every weaker source is ignored when a stronger one is present
        assert_eq!(DispatchMode::resolve(Some(D), None, Some(C)), Some(D));
        assert_eq!(DispatchMode::resolve(None, Some(C), None), Some(C));
    }

    #[test]
    fn phase_plan_helpers() {
        use DispatchMode::{Centralized as C, Decentralized as D};
        let plan = PhasePlan { threshold: 4, modes: vec![C, D, D, C] };
        assert_eq!(plan.mode_switches(), 2);
        assert_eq!(PhasePlan::uniform(4, C, 3).mode_switches(), 0);
        assert!(plan.render().starts_with("c|d|d|c"));
        assert!(plan.render().contains("threshold 4"));
    }

    #[test]
    fn width_plan_helpers() {
        let mut plan = WidthPlan::uniform(1);
        assert!(plan.is_uniform_one());
        assert_eq!(plan.max_width(), 1);
        plan.set(OpClass::Gemm, 4);
        plan.set(OpClass::Conv, 2);
        assert!(!plan.is_uniform_one());
        assert_eq!(plan.width_for(OpClass::Gemm), 4);
        assert_eq!(plan.width_for(OpClass::Elementwise), 1);
        assert_eq!(plan.max_width(), 4);
        assert_eq!(plan.render(), "gemm:4 conv:2 elementwise:1 memory:1 tiny:1");
        // out-of-range widths clamp instead of corrupting the entry field
        plan.set(OpClass::Memory, 99);
        assert_eq!(plan.width_for(OpClass::Memory), ready::MAX_WIDTH);
        plan.set(OpClass::Memory, 0);
        assert_eq!(plan.width_for(OpClass::Memory), 1);
        assert_eq!(WidthPlan::default(), WidthPlan::uniform(1));
    }

    #[test]
    fn width_plan_parse_accepts_specs_and_rejects_garbage() {
        let plan = WidthPlan::parse("gemm=4, conv:2").unwrap();
        assert_eq!(plan.width_for(OpClass::Gemm), 4);
        assert_eq!(plan.width_for(OpClass::Conv), 2);
        assert_eq!(plan.width_for(OpClass::Elementwise), 1);
        // the empty spec is the identity plan
        assert_eq!(WidthPlan::parse("").unwrap(), WidthPlan::uniform(1));
        assert!(WidthPlan::parse("warp=2").unwrap_err().contains("unknown op class"));
        assert!(WidthPlan::parse("gemm=0").unwrap_err().contains("outside"));
        assert!(WidthPlan::parse(&format!("gemm={}", ready::MAX_WIDTH + 1)).is_err());
        assert!(WidthPlan::parse("gemm").unwrap_err().contains("class=width"));
    }

    #[test]
    fn metrics_utilization() {
        let m = EngineMetrics {
            executor_busy_us: vec![50.0, 100.0],
            ..Default::default()
        };
        assert!((m.utilization(100.0) - 0.75).abs() < 1e-12);
        assert_eq!(EngineMetrics::default().utilization(10.0), 0.0);
    }
}
