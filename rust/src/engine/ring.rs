//! Lock-free single-producer/single-consumer ring buffer.
//!
//! §5.2: "The operation buffer is implemented with a lock free ring buffer
//! for high efficiency. This implementation is inspired by the per-thread
//! run queue of MuQSS." In Graphi the scheduler is the only producer and
//! one executor the only consumer, so an SPSC ring with acquire/release
//! atomics suffices — no CAS loops, no sharing between executors.
//!
//! This is *real* concurrent code (used by the threaded engine in
//! [`crate::runtime::threaded`]); the simulated engines use it too, via
//! the same API, so the data structure under test is the one that runs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::cell::UnsafeCell;

/// Fixed-capacity SPSC ring buffer.
///
/// Capacity is rounded up to a power of two. One slot is sacrificed to
/// distinguish full from empty.
pub struct SpscRing<T> {
    buf: Box<[UnsafeCell<Option<T>>]>,
    mask: usize,
    /// Next slot to write (owned by the producer).
    head: AtomicUsize,
    /// Next slot to read (owned by the consumer).
    tail: AtomicUsize,
}

// SAFETY: head/tail partitioning guarantees producer and consumer never
// touch the same slot concurrently; Option<T> slots are only accessed by
// the side that owns them at that index.
unsafe impl<T: Send> Send for SpscRing<T> {}
unsafe impl<T: Send> Sync for SpscRing<T> {}

impl<T> SpscRing<T> {
    /// Create a ring holding at least `capacity` items.
    pub fn new(capacity: usize) -> SpscRing<T> {
        let cap = (capacity + 1).next_power_of_two();
        let buf: Vec<UnsafeCell<Option<T>>> = (0..cap).map(|_| UnsafeCell::new(None)).collect();
        SpscRing {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    /// Producer side: push an item; returns `Err(item)` if full.
    pub fn push(&self, item: T) -> Result<(), T> {
        let head = self.head.load(Ordering::Relaxed);
        let next = (head + 1) & self.mask;
        if next == self.tail.load(Ordering::Acquire) {
            return Err(item); // full
        }
        // SAFETY: slot `head` is owned by the producer until head is
        // published below.
        unsafe {
            *self.buf[head].get() = Some(item);
        }
        self.head.store(next, Ordering::Release);
        Ok(())
    }

    /// Consumer side: pop the oldest item, if any.
    pub fn pop(&self) -> Option<T> {
        let tail = self.tail.load(Ordering::Relaxed);
        if tail == self.head.load(Ordering::Acquire) {
            return None; // empty
        }
        // SAFETY: slot `tail` is owned by the consumer until tail is
        // published below.
        let item = unsafe { (*self.buf[tail].get()).take() };
        self.tail.store((tail + 1) & self.mask, Ordering::Release);
        item
    }

    /// Number of buffered items (approximate under concurrency).
    pub fn len(&self) -> usize {
        let head = self.head.load(Ordering::Acquire);
        let tail = self.tail.load(Ordering::Acquire);
        (head.wrapping_sub(tail)) & self.mask
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Usable capacity.
    pub fn capacity(&self) -> usize {
        self.mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let r = SpscRing::new(4);
        r.push(1).unwrap();
        r.push(2).unwrap();
        r.push(3).unwrap();
        assert_eq!(r.pop(), Some(1));
        assert_eq!(r.pop(), Some(2));
        r.push(4).unwrap();
        assert_eq!(r.pop(), Some(3));
        assert_eq!(r.pop(), Some(4));
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn capacity_enforced() {
        let r = SpscRing::new(1); // rounds to 2 slots, 1 usable
        assert_eq!(r.capacity(), 1);
        r.push("a").unwrap();
        assert_eq!(r.push("b"), Err("b"));
        assert_eq!(r.pop(), Some("a"));
        r.push("b").unwrap();
    }

    #[test]
    fn wraparound_many_times() {
        let r = SpscRing::new(3);
        for i in 0..100 {
            r.push(i).unwrap();
            assert_eq!(r.pop(), Some(i));
        }
        assert!(r.is_empty());
    }

    #[test]
    fn len_tracks_occupancy() {
        let r = SpscRing::new(8);
        assert_eq!(r.len(), 0);
        for i in 0..5 {
            r.push(i).unwrap();
        }
        assert_eq!(r.len(), 5);
        r.pop();
        assert_eq!(r.len(), 4);
    }

    #[test]
    fn cross_thread_spsc_stress() {
        // one producer thread, one consumer thread, every item accounted
        // for exactly once and in order
        let r = Arc::new(SpscRing::<u64>::new(64));
        let n = 100_000u64;
        let producer = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                for i in 0..n {
                    let mut item = i;
                    loop {
                        match r.push(item) {
                            Ok(()) => break,
                            Err(back) => {
                                item = back;
                                std::hint::spin_loop();
                            }
                        }
                    }
                }
            })
        };
        let consumer = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                let mut expected = 0u64;
                while expected < n {
                    if let Some(v) = r.pop() {
                        assert_eq!(v, expected, "out-of-order item");
                        expected += 1;
                    } else {
                        std::hint::spin_loop();
                    }
                }
            })
        };
        producer.join().unwrap();
        consumer.join().unwrap();
        assert!(r.is_empty());
    }

    #[test]
    fn drops_not_leaked() {
        // items left in the ring are dropped with it
        use std::rc::Rc;
        let flag = Rc::new(());
        let r = SpscRing::new(4);
        r.push(Rc::clone(&flag)).unwrap();
        r.push(Rc::clone(&flag)).unwrap();
        assert_eq!(Rc::strong_count(&flag), 3);
        drop(r);
        assert_eq!(Rc::strong_count(&flag), 1);
    }
}
