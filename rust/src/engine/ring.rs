//! Lock-free single-producer/single-consumer ring buffer.
//!
//! §5.2: "The operation buffer is implemented with a lock free ring buffer
//! for high efficiency. This implementation is inspired by the per-thread
//! run queue of MuQSS." In Graphi the scheduler is the only producer and
//! one executor the only consumer, so an SPSC ring with acquire/release
//! atomics suffices — no CAS loops, no sharing between executors.
//!
//! This is *real* concurrent code (used by the threaded engine in
//! [`crate::runtime::threaded`]); the simulated engines use it too, via
//! the same API, so the data structure under test is the one that runs.
//!
//! # Layout and the cached-opposite-index optimisation
//!
//! The producer's state (`head`, plus its cached copy of the consumer's
//! `tail`) and the consumer's state (`tail`, plus its cached copy of
//! `head`) live in **separate 64-byte-aligned groups**, so a push never
//! invalidates the cache line the consumer spins on and vice versa — the
//! classic false-sharing fix for SPSC rings.
//!
//! Each side also **caches the last observed opposite index**: a push only
//! performs an acquire load of `tail` when its cached copy says the ring
//! *might* be full (and symmetrically for pop). While the ring has slack,
//! push/pop touch no shared cache line at all except their own published
//! index, and the batch APIs ([`SpscRing::push_batch`] /
//! [`SpscRing::pop_batch`]) amortise even that store over the whole batch.
//!
//! Slots are `MaybeUninit<T>` rather than `Option<T>`: occupancy is
//! tracked entirely by the head/tail indices, so no discriminant is
//! written or branch taken per slot transfer, and `pop` moves the value
//! out with a plain read.
//!
//! # Safety contract
//!
//! At most one thread may call producer methods (`push`, `push_batch`)
//! concurrently, and at most one thread may call consumer methods (`pop`,
//! `pop_batch`) concurrently. The engines uphold this by construction:
//! the scheduler thread is the sole producer and each executor owns its
//! ring's consumer side.

use std::cell::{Cell, UnsafeCell};
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Producer-owned state, on its own cache line: the write index plus the
/// producer's snapshot of the consumer's read index.
#[repr(align(64))]
struct ProducerSide {
    /// Next slot to write (owned by the producer, read by the consumer).
    head: AtomicUsize,
    /// Last `tail` value the producer observed (producer-private).
    tail_cache: Cell<usize>,
}

/// Consumer-owned state, on its own cache line: the read index plus the
/// consumer's snapshot of the producer's write index.
#[repr(align(64))]
struct ConsumerSide {
    /// Next slot to read (owned by the consumer, read by the producer).
    tail: AtomicUsize,
    /// Last `head` value the consumer observed (consumer-private).
    head_cache: Cell<usize>,
}

/// Fixed-capacity SPSC ring buffer.
///
/// Capacity is rounded up to a power of two. One slot is sacrificed to
/// distinguish full from empty.
pub struct SpscRing<T> {
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    mask: usize,
    prod: ProducerSide,
    cons: ConsumerSide,
}

// SAFETY: head/tail partitioning guarantees producer and consumer never
// touch the same slot concurrently; the `Cell` index caches are private to
// their respective side under the one-producer/one-consumer contract
// documented on the type.
unsafe impl<T: Send> Send for SpscRing<T> {}
unsafe impl<T: Send> Sync for SpscRing<T> {}

impl<T> SpscRing<T> {
    /// Create a ring holding at least `capacity` items.
    pub fn new(capacity: usize) -> SpscRing<T> {
        let cap = (capacity + 1).next_power_of_two();
        let buf: Vec<UnsafeCell<MaybeUninit<T>>> =
            (0..cap).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
        SpscRing {
            buf: buf.into_boxed_slice(),
            mask: cap - 1,
            prod: ProducerSide { head: AtomicUsize::new(0), tail_cache: Cell::new(0) },
            cons: ConsumerSide { tail: AtomicUsize::new(0), head_cache: Cell::new(0) },
        }
    }

    /// Producer side: push an item; returns `Err(item)` if full.
    pub fn push(&self, item: T) -> Result<(), T> {
        let head = self.prod.head.load(Ordering::Relaxed);
        let next = (head + 1) & self.mask;
        if next == self.prod.tail_cache.get() {
            // cached view says full — refresh from the shared index
            self.prod.tail_cache.set(self.cons.tail.load(Ordering::Acquire));
            if next == self.prod.tail_cache.get() {
                return Err(item); // actually full
            }
        }
        // SAFETY: slot `head` is owned by the producer until head is
        // published below.
        unsafe {
            (*self.buf[head].get()).write(item);
        }
        self.prod.head.store(next, Ordering::Release);
        Ok(())
    }

    /// Producer side: push items from `items` until the ring fills or the
    /// iterator ends; returns the number pushed. The head index is
    /// published **once** at the end, so consumers see the whole batch
    /// atomically and the producer pays one release store per batch.
    pub fn push_batch<I: Iterator<Item = T>>(&self, items: &mut I) -> usize {
        let start = self.prod.head.load(Ordering::Relaxed);
        let mut head = start;
        let mut pushed = 0usize;
        loop {
            let next = (head + 1) & self.mask;
            if next == self.prod.tail_cache.get() {
                self.prod.tail_cache.set(self.cons.tail.load(Ordering::Acquire));
                if next == self.prod.tail_cache.get() {
                    break; // full
                }
            }
            let Some(item) = items.next() else { break };
            // SAFETY: slots `start..head` (mod capacity) are owned by the
            // producer until the single publish below.
            unsafe {
                (*self.buf[head].get()).write(item);
            }
            head = next;
            pushed += 1;
        }
        if head != start {
            self.prod.head.store(head, Ordering::Release);
        }
        pushed
    }

    /// Consumer side: pop the oldest item, if any.
    pub fn pop(&self) -> Option<T> {
        let tail = self.cons.tail.load(Ordering::Relaxed);
        if tail == self.cons.head_cache.get() {
            // cached view says empty — refresh from the shared index
            self.cons.head_cache.set(self.prod.head.load(Ordering::Acquire));
            if tail == self.cons.head_cache.get() {
                return None; // actually empty
            }
        }
        // SAFETY: slot `tail` is owned by the consumer until tail is
        // published below; the producer initialised it before publishing
        // `head` past it.
        let item = unsafe { (*self.buf[tail].get()).assume_init_read() };
        self.cons.tail.store((tail + 1) & self.mask, Ordering::Release);
        Some(item)
    }

    /// Consumer side: pop up to `max` items into `out`; returns the number
    /// popped. The tail index is published **once** at the end.
    pub fn pop_batch(&self, out: &mut Vec<T>, max: usize) -> usize {
        let start = self.cons.tail.load(Ordering::Relaxed);
        let mut tail = start;
        let mut popped = 0usize;
        while popped < max {
            if tail == self.cons.head_cache.get() {
                self.cons.head_cache.set(self.prod.head.load(Ordering::Acquire));
                if tail == self.cons.head_cache.get() {
                    break; // empty
                }
            }
            // SAFETY: slots `start..tail` (mod capacity) are owned by the
            // consumer until the single publish below.
            out.push(unsafe { (*self.buf[tail].get()).assume_init_read() });
            tail = (tail + 1) & self.mask;
            popped += 1;
        }
        if tail != start {
            self.cons.tail.store(tail, Ordering::Release);
        }
        popped
    }

    /// Number of buffered items (approximate under concurrency).
    pub fn len(&self) -> usize {
        let head = self.prod.head.load(Ordering::Acquire);
        let tail = self.cons.tail.load(Ordering::Acquire);
        (head.wrapping_sub(tail)) & self.mask
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Usable capacity.
    pub fn capacity(&self) -> usize {
        self.mask
    }
}

impl<T> Drop for SpscRing<T> {
    fn drop(&mut self) {
        // `&mut self` ⇒ no concurrent access; drop any undelivered items
        let head = *self.prod.head.get_mut();
        let mut tail = *self.cons.tail.get_mut();
        while tail != head {
            // SAFETY: slots in [tail, head) hold initialised items
            unsafe {
                std::ptr::drop_in_place((*self.buf[tail].get()).as_mut_ptr());
            }
            tail = (tail + 1) & self.mask;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order() {
        let r = SpscRing::new(4);
        r.push(1).unwrap();
        r.push(2).unwrap();
        r.push(3).unwrap();
        assert_eq!(r.pop(), Some(1));
        assert_eq!(r.pop(), Some(2));
        r.push(4).unwrap();
        assert_eq!(r.pop(), Some(3));
        assert_eq!(r.pop(), Some(4));
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn capacity_enforced() {
        let r = SpscRing::new(1); // rounds to 2 slots, 1 usable
        assert_eq!(r.capacity(), 1);
        r.push("a").unwrap();
        assert_eq!(r.push("b"), Err("b"));
        assert_eq!(r.pop(), Some("a"));
        r.push("b").unwrap();
    }

    #[test]
    fn wraparound_many_times() {
        let r = SpscRing::new(3);
        for i in 0..100 {
            r.push(i).unwrap();
            assert_eq!(r.pop(), Some(i));
        }
        assert!(r.is_empty());
    }

    #[test]
    fn len_tracks_occupancy() {
        let r = SpscRing::new(8);
        assert_eq!(r.len(), 0);
        for i in 0..5 {
            r.push(i).unwrap();
        }
        assert_eq!(r.len(), 5);
        r.pop();
        assert_eq!(r.len(), 4);
    }

    #[test]
    fn batch_push_pop_roundtrip() {
        let r: SpscRing<u32> = SpscRing::new(8);
        let mut items = 0..6u32;
        assert_eq!(r.push_batch(&mut items), 6);
        assert!(items.next().is_none(), "iterator fully consumed");
        let mut out = Vec::new();
        assert_eq!(r.pop_batch(&mut out, 4), 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(r.pop_batch(&mut out, 100), 2);
        assert_eq!(out, vec![0, 1, 2, 3, 4, 5]);
        assert!(r.is_empty());
    }

    #[test]
    fn batch_push_stops_at_capacity() {
        let r: SpscRing<u32> = SpscRing::new(3); // 4 slots, 3 usable
        let mut items = 0..10u32;
        assert_eq!(r.push_batch(&mut items), 3);
        // the 4th item was not consumed from the iterator
        assert_eq!(items.next(), Some(3));
        assert_eq!(r.len(), 3);
        let mut out = Vec::new();
        r.pop_batch(&mut out, 10);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn batch_on_empty_and_full_are_noops() {
        let r: SpscRing<u8> = SpscRing::new(2);
        let mut out = Vec::new();
        assert_eq!(r.pop_batch(&mut out, 5), 0);
        assert!(out.is_empty());
        let mut none = std::iter::empty::<u8>();
        assert_eq!(r.push_batch(&mut none), 0);
        assert!(r.is_empty());
    }

    #[test]
    fn interleaved_single_and_batch() {
        let r: SpscRing<u32> = SpscRing::new(8);
        r.push(100).unwrap();
        let mut items = 0..3u32;
        r.push_batch(&mut items);
        assert_eq!(r.pop(), Some(100));
        let mut out = Vec::new();
        r.pop_batch(&mut out, 2);
        assert_eq!(out, vec![0, 1]);
        assert_eq!(r.pop(), Some(2));
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn cross_thread_spsc_stress() {
        // one producer thread, one consumer thread, every item accounted
        // for exactly once and in order
        let r = Arc::new(SpscRing::<u64>::new(64));
        let n = 100_000u64;
        let producer = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                for i in 0..n {
                    let mut item = i;
                    loop {
                        match r.push(item) {
                            Ok(()) => break,
                            Err(back) => {
                                item = back;
                                std::hint::spin_loop();
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            })
        };
        let consumer = {
            let r = Arc::clone(&r);
            std::thread::spawn(move || {
                let mut expected = 0u64;
                while expected < n {
                    if let Some(v) = r.pop() {
                        assert_eq!(v, expected, "out-of-order item");
                        expected += 1;
                    } else {
                        std::hint::spin_loop();
                        std::thread::yield_now();
                    }
                }
            })
        };
        producer.join().unwrap();
        consumer.join().unwrap();
        assert!(r.is_empty());
    }

    #[test]
    fn drops_not_leaked() {
        // items left in the ring are dropped with it
        use std::rc::Rc;
        let flag = Rc::new(());
        let r = SpscRing::new(4);
        r.push(Rc::clone(&flag)).unwrap();
        r.push(Rc::clone(&flag)).unwrap();
        assert_eq!(Rc::strong_count(&flag), 3);
        drop(r);
        assert_eq!(Rc::strong_count(&flag), 1);
    }

    #[test]
    fn drops_not_leaked_after_wraparound() {
        use std::rc::Rc;
        let flag = Rc::new(());
        let r = SpscRing::new(2);
        // advance past the wrap point, leaving two items resident
        for _ in 0..5 {
            r.push(Rc::clone(&flag)).unwrap();
            r.pop().unwrap();
        }
        r.push(Rc::clone(&flag)).unwrap();
        r.push(Rc::clone(&flag)).unwrap();
        assert_eq!(Rc::strong_count(&flag), 3);
        drop(r);
        assert_eq!(Rc::strong_count(&flag), 1);
    }
}
