//! Dependency tracking and the ready-operation set.
//!
//! `DepTracker` owns the per-node remaining-dependency counts (the
//! "triggering" in Algorithm 2); `ReadySet` owns the ordering of ready ops
//! under a [`Policy`] (the max heap of §5.2 for critical-path-first).
//! Both are shared by every engine — simulated and threaded — so the data
//! structures being benchmarked are the ones actually scheduling.
//!
//! # The packed-key d-ary heap
//!
//! The level-priority policies (`CriticalPathFirst`, `AntiCritical`) used
//! to run on a `BinaryHeap` of 24-byte `{f64 priority, u64 seq, u32 node}`
//! entries, paying an `f64::total_cmp` plus a `u64` compare per sift step.
//! The hot path now packs each entry into a **single `u64`**:
//!
//! ```text
//!   63                    32 31                     0
//!   +-----------------------+-----------------------+
//!   |  quantized priority   |   !seq (inverted)     |
//!   +-----------------------+-----------------------+
//! ```
//!
//! * The **priority** field is the top 32 bits of the standard
//!   order-preserving map from `f64` to `u64` (flip all bits of negative
//!   values, set the sign bit of non-negative ones — the same total order
//!   as `f64::total_cmp`). Larger level ⇒ larger field.
//! * The **sequence** field stores the bitwise NOT of the push sequence
//!   number, so that when two priorities quantize equal, the *larger*
//!   packed key belongs to the *earlier* push — a plain `u64` max-compare
//!   yields FIFO tie-breaking with zero extra branches.
//!
//! The heap itself is a flat 4-ary max-heap over a contiguous `Vec<u64>`:
//! shallower than a binary heap (log₄ vs log₂ levels), with all four
//! children on one cache line, and every comparison a single integer
//! compare.
//!
//! ## Quantization tie-break guarantee
//!
//! Quantization keeps the top 32 bits of the 64-bit total-order map, so:
//!
//! * any two levels that are **exactly equal** as `f64` quantize equal and
//!   therefore break ties FIFO — identical to the previous
//!   `total_cmp`-then-seq behaviour;
//! * any two levels whose total-order maps differ in the top 32 bits (in
//!   practice: relative difference ≳ 2⁻²⁰, i.e. anything but
//!   almost-identical critical-path lengths) keep their **exact** relative
//!   order;
//! * levels that differ only below the top 32 bits fall into the same
//!   bucket and dispatch FIFO between themselves. This is a deliberate
//!   trade: ops whose critical paths agree to within one part in a million
//!   are schedule-equivalent, and FIFO among them preserves determinism.
//!
//! The sequence counter resets whenever the set drains empty (tie-break
//! order is only observable among co-resident entries), so 32 bits of
//! sequence bound the *occupancy between drains*, not the lifetime push
//! count.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::graph::{Graph, NodeId};
use crate::util::rng::Rng;

use super::policies::Policy;

/// Remaining-dependency counters.
#[derive(Debug, Clone)]
pub struct DepTracker {
    indegree: Vec<u32>,
    remaining: usize,
}

impl DepTracker {
    pub fn new(graph: &Graph) -> DepTracker {
        let indegree: Vec<u32> = (0..graph.len() as NodeId)
            .map(|v| graph.in_degree(v) as u32)
            .collect();
        DepTracker { indegree, remaining: graph.len() }
    }

    /// Nodes with no dependencies (call once at start).
    pub fn sources(&self) -> Vec<NodeId> {
        self.indegree
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i as NodeId)
            .collect()
    }

    /// Mark `node` executed; invoke `on_ready` for each newly-triggered op.
    pub fn complete(&mut self, graph: &Graph, node: NodeId, mut on_ready: impl FnMut(NodeId)) {
        debug_assert!(self.remaining > 0);
        self.remaining -= 1;
        for &s in graph.succs(node) {
            let d = &mut self.indegree[s as usize];
            debug_assert!(*d > 0, "double trigger of node {s}");
            *d -= 1;
            if *d == 0 {
                on_ready(s);
            }
        }
    }

    /// Ops not yet executed.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    pub fn is_done(&self) -> bool {
        self.remaining == 0
    }
}

/// Order-preserving map from `f64` to `u64` (the `total_cmp` order), then
/// truncated to the top 32 bits. See the module docs for the tie-break
/// guarantee this truncation makes.
#[inline]
fn quantize(priority: f64) -> u32 {
    let bits = priority.to_bits();
    let mapped = if bits >> 63 == 1 { !bits } else { bits | 0x8000_0000_0000_0000 };
    (mapped >> 32) as u32
}

#[inline]
fn pack(priority: f64, seq: u32) -> u64 {
    ((quantize(priority) as u64) << 32) | ((!seq) as u64)
}

/// Bits of the quantized CP level in every packed deque key (high half).
pub const ENTRY_LEVEL_BITS: u32 = 32;

/// Bits of the moldable gang-width field. The field stores `width - 1`,
/// so `w = 1` entries carry all-zero width bits and stay **bit-identical**
/// to the pre-moldable packings.
pub const ENTRY_WIDTH_BITS: u32 = 4;

/// Largest gang width a packed entry can carry.
pub const MAX_WIDTH: u32 = 1 << ENTRY_WIDTH_BITS;

/// Bits of the session-slot field in a serve-mode key.
pub const SESSION_SLOT_BITS: u32 = 8;

/// Bits of a serve-mode key's node field. Session graphs are capped at
/// 2²⁰ nodes (far above every model in the zoo); above the node field sit
/// the gang width ([`ENTRY_WIDTH_BITS`]) and the session slot
/// ([`SESSION_SLOT_BITS`]).
pub const SESSION_NODE_BITS: u32 = 20;

/// Bits of the single-graph key's node field ([`pack_entry`]); the
/// [`ENTRY_WIDTH_BITS`] above it carry the gang width.
pub const PLAIN_NODE_BITS: u32 = 28;

// Compile-time layout checks: the fields of each packing must tile
// exactly 64 bits, the slot field must still address all 256 fleet
// session slots, and the width field must hold `MAX_WIDTH - 1`. A
// mis-sized width field would silently shift into the level half and
// corrupt CP ranking — fail the build instead.
const _: () = assert!(
    ENTRY_LEVEL_BITS + SESSION_SLOT_BITS + ENTRY_WIDTH_BITS + SESSION_NODE_BITS == 64,
    "session key fields must tile 64 bits exactly"
);
const _: () = assert!(
    ENTRY_LEVEL_BITS + ENTRY_WIDTH_BITS + PLAIN_NODE_BITS == 64,
    "single-graph key fields must tile 64 bits exactly"
);
const _: () =
    assert!(1usize << SESSION_SLOT_BITS == 256, "slot field must address exactly 256 slots");
const _: () = assert!(MAX_WIDTH >= 1 && MAX_WIDTH <= 1 << ENTRY_WIDTH_BITS);

/// Pack a `(priority, node)` pair into one `u64` for the work-stealing
/// deques ([`crate::engine::worksteal`]): quantized priority in the high
/// half (same order-preserving map as the ready-heap keys), the node id in
/// the low half. A plain integer max-compare orders entries by priority;
/// priorities that quantize equal tie-break by node id — arbitrary but
/// deterministic, which is all the decentralized path needs (cross-thread
/// FIFO seniority is not observable anyway). Equivalent to
/// [`pack_entry_wide`] at width 1 (the width bits stay zero).
#[inline]
pub fn pack_entry(priority: f64, node: NodeId) -> u64 {
    debug_assert!(node < (1 << PLAIN_NODE_BITS), "node {node} exceeds the key's node field");
    ((quantize(priority) as u64) << ENTRY_LEVEL_BITS) | node as u64
}

/// [`pack_entry`] with an explicit gang width `w` in `1..=MAX_WIDTH`:
///
/// ```text
///   63              32 31   28 27               0
///   +-----------------+-------+-----------------+
///   | quantized level | w - 1 |     node id     |
///   +-----------------+-------+-----------------+
/// ```
///
/// The width field stores `w - 1`, so `w = 1` produces exactly
/// [`pack_entry`]'s key and width-free runs stay bit-compatible. The
/// level half is untouched, so CP ranking and the NUMA cross-domain
/// margin ([`crate::engine::worksteal::entry_level`]) order wide entries
/// identically to narrow ones.
#[inline]
pub fn pack_entry_wide(priority: f64, node: NodeId, width: u32) -> u64 {
    debug_assert!(width >= 1 && width <= MAX_WIDTH, "gang width {width} out of range");
    pack_entry(priority, node) | (((width - 1) as u64) << PLAIN_NODE_BITS)
}

/// The node id carried by a [`pack_entry`]/[`pack_entry_wide`] key.
#[inline]
pub fn entry_node(key: u64) -> NodeId {
    (key as u32) & ((1 << PLAIN_NODE_BITS) - 1)
}

/// The gang width carried by a [`pack_entry_wide`] key (1 for plain keys).
#[inline]
pub fn entry_width(key: u64) -> u32 {
    (((key >> PLAIN_NODE_BITS) as u32) & (MAX_WIDTH - 1)) + 1
}

/// Pack a `(priority, session slot, node)` triple into one `u64` for the
/// multi-session executor fleet ([`crate::runtime::fleet`]):
///
/// ```text
///   63              32 31     24 23   20 19           0
///   +-----------------+---------+-------+-------------+
///   | quantized level |  slot   | w - 1 |   node id   |
///   +-----------------+---------+-------+-------------+
/// ```
///
/// The level field is identical to [`pack_entry`]'s, so a plain integer
/// max-compare still orders entries by critical-path priority — now
/// *across sessions*: an op deep on graph A's critical path outranks a
/// shallow op of graph B by the same rule that orders them within one
/// graph. Priorities that quantize equal tie-break by (slot, width, node)
/// — arbitrary but deterministic, same contract as [`pack_entry`]. The
/// NUMA victim ranking's [`crate::engine::worksteal::entry_level`]
/// reads only the high half and is layout-compatible with both packings.
/// The width field stores `w - 1` (here always 0), so width-1 keys are
/// bit-identical to the pre-moldable 24-bit-node packing for every graph
/// below 2²⁰ nodes. [`pack_session_entry_wide`] sets a real width.
#[inline]
pub fn pack_session_entry(priority: f64, slot: u8, node: NodeId) -> u64 {
    pack_session_entry_wide(priority, slot, node, 1)
}

/// [`pack_session_entry`] with an explicit gang width in `1..=MAX_WIDTH`.
#[inline]
pub fn pack_session_entry_wide(priority: f64, slot: u8, node: NodeId, width: u32) -> u64 {
    debug_assert!(node < (1 << SESSION_NODE_BITS), "node {node} exceeds the session key's node field");
    debug_assert!(width >= 1 && width <= MAX_WIDTH, "gang width {width} out of range");
    ((quantize(priority) as u64) << ENTRY_LEVEL_BITS)
        | ((slot as u64) << (SESSION_NODE_BITS + ENTRY_WIDTH_BITS))
        | (((width - 1) as u64) << SESSION_NODE_BITS)
        | node as u64
}

/// The session slot carried by a [`pack_session_entry`] key.
#[inline]
pub fn session_entry_slot(key: u64) -> u8 {
    (key >> (SESSION_NODE_BITS + ENTRY_WIDTH_BITS)) as u8
}

/// The gang width carried by a [`pack_session_entry_wide`] key (1 for
/// plain session keys).
#[inline]
pub fn session_entry_width(key: u64) -> u32 {
    (((key >> SESSION_NODE_BITS) as u32) & (MAX_WIDTH - 1)) + 1
}

/// The node id carried by a [`pack_session_entry`] key.
#[inline]
pub fn session_entry_node(key: u64) -> NodeId {
    (key as u32) & ((1 << SESSION_NODE_BITS) - 1)
}

/// Arity of the flat heap. 4 keeps all children of a node within one
/// 64-byte cache line of `Vec<u64>` storage.
const D: usize = 4;

/// The set of ready-to-run operations, ordered by policy.
#[derive(Debug)]
pub struct ReadySet {
    policy: Policy,
    levels: Arc<[f64]>,
    /// Flat 4-ary max-heap of packed keys (level policies only).
    heap: Vec<u64>,
    /// Push-sequence → node lookup for the packed heap; indexed by the
    /// sequence number recovered from a popped key. Cleared when the set
    /// drains empty.
    nodes: Vec<NodeId>,
    queue: VecDeque<NodeId>,
    stack: Vec<NodeId>,
    rng: Rng,
    seq: u32,
    len: usize,
}

impl ReadySet {
    /// `levels` supplies priorities for the level-based policies; pass the
    /// output of [`crate::graph::levels`] (or unit estimates). Accepts
    /// `Vec<f64>`, `&[f64]`, or a shared `Arc<[f64]>` — the slice is moved
    /// or reference-counted, never re-cloned per run by the callee.
    pub fn new(policy: Policy, levels: impl Into<Arc<[f64]>>, seed: u64) -> ReadySet {
        ReadySet {
            policy,
            levels: levels.into(),
            heap: Vec::new(),
            nodes: Vec::new(),
            queue: VecDeque::new(),
            stack: Vec::new(),
            rng: Rng::new(seed),
            seq: 0,
            len: 0,
        }
    }

    #[inline]
    fn heap_insert(&mut self, priority: f64, node: NodeId) {
        if self.heap.is_empty() {
            // tie-break order is only observable among co-resident
            // entries, so the sequence (and the seq→node table) restart
            // whenever the set drains
            self.seq = 0;
            self.nodes.clear();
        }
        let seq = self.seq;
        self.seq += 1;
        self.nodes.push(node);
        let key = pack(priority, seq);
        // sift up
        let mut i = self.heap.len();
        self.heap.push(key);
        while i > 0 {
            let parent = (i - 1) / D;
            if self.heap[parent] >= key {
                break;
            }
            self.heap[i] = self.heap[parent];
            i = parent;
        }
        self.heap[i] = key;
    }

    #[inline]
    fn heap_remove_max(&mut self) -> Option<NodeId> {
        let top = *self.heap.first()?;
        let last = self.heap.pop().unwrap();
        let n = self.heap.len();
        if n > 0 {
            // sift `last` down from the root
            let mut i = 0;
            loop {
                let first_child = D * i + 1;
                if first_child >= n {
                    break;
                }
                let end = (first_child + D).min(n);
                let mut best = first_child;
                let mut best_key = self.heap[first_child];
                let mut c = first_child + 1;
                while c < end {
                    if self.heap[c] > best_key {
                        best = c;
                        best_key = self.heap[c];
                    }
                    c += 1;
                }
                if last >= best_key {
                    break;
                }
                self.heap[i] = best_key;
                i = best;
            }
            self.heap[i] = last;
        }
        let seq = !(top as u32);
        Some(self.nodes[seq as usize])
    }

    pub fn push(&mut self, node: NodeId) {
        self.len += 1;
        match self.policy {
            Policy::CriticalPathFirst => {
                let priority = self.levels[node as usize];
                self.heap_insert(priority, node);
            }
            Policy::AntiCritical => {
                let priority = -self.levels[node as usize];
                self.heap_insert(priority, node);
            }
            Policy::Fifo => self.queue.push_back(node),
            Policy::Lifo => self.stack.push(node),
            Policy::Random => self.stack.push(node),
        }
    }

    pub fn pop(&mut self) -> Option<NodeId> {
        let out = match self.policy {
            Policy::CriticalPathFirst | Policy::AntiCritical => self.heap_remove_max(),
            Policy::Fifo => self.queue.pop_front(),
            Policy::Lifo => self.stack.pop(),
            Policy::Random => {
                if self.stack.is_empty() {
                    None
                } else {
                    let i = self.rng.range(0, self.stack.len());
                    Some(self.stack.swap_remove(i))
                }
            }
        };
        if out.is_some() {
            self.len -= 1;
        }
        out
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;
    use crate::graph::GraphBuilder;

    #[test]
    fn dep_tracker_triggers_in_order() {
        let mut b = GraphBuilder::new();
        let a = b.add("a", OpKind::Scalar);
        let c = b.add("c", OpKind::Scalar);
        let d = b.add_after("d", OpKind::Scalar, &[a, c]);
        let g = b.build().unwrap();
        let mut t = DepTracker::new(&g);
        assert_eq!(t.sources(), vec![a, c]);
        let mut fired = Vec::new();
        t.complete(&g, a, |n| fired.push(n));
        assert!(fired.is_empty(), "d still blocked on c");
        t.complete(&g, c, |n| fired.push(n));
        assert_eq!(fired, vec![d]);
        t.complete(&g, d, |_| {});
        assert!(t.is_done());
    }

    #[test]
    fn quantize_preserves_order() {
        let samples = [
            -1e9, -5000.0, -1.0, -1e-3, 0.0, 1e-3, 0.5, 1.0, 5.0, 10.0, 50.0, 4096.0, 1e6, 1e12,
        ];
        for w in samples.windows(2) {
            assert!(
                quantize(w[0]) < quantize(w[1]),
                "quantize({}) !< quantize({})",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn pack_entry_orders_by_priority_then_node() {
        let max_node = (1 << PLAIN_NODE_BITS) - 1;
        assert!(pack_entry(9.0, 0) > pack_entry(5.0, 1000), "priority dominates");
        assert!(pack_entry(7.0, 2) > pack_entry(7.0, 1), "equal priority: node id breaks ties");
        assert_eq!(entry_node(pack_entry(123.0, 77)), 77);
        assert_eq!(entry_node(pack_entry(-4.5, max_node)), max_node);
    }

    #[test]
    fn wide_entry_roundtrips_and_width_one_is_bit_identical() {
        let max_node = (1 << PLAIN_NODE_BITS) - 1;
        for (level, node) in [(0.0, 0u32), (123.5, 42), (-4.5, max_node)] {
            for width in [1u32, 2, 3, MAX_WIDTH] {
                let key = pack_entry_wide(level, node, width);
                assert_eq!(entry_node(key), node);
                assert_eq!(entry_width(key), width);
                // the level half is never disturbed by the width field
                assert_eq!(key >> ENTRY_LEVEL_BITS, pack_entry(level, node) >> ENTRY_LEVEL_BITS);
            }
            // w = 1 is the pre-moldable packing, bit for bit
            assert_eq!(pack_entry_wide(level, node, 1), pack_entry(level, node));
        }
        assert_eq!(entry_width(pack_entry(3.0, 17)), 1, "plain keys decode as width 1");
    }

    #[test]
    fn session_entry_roundtrips_and_orders_across_sessions() {
        let max_node = (1 << SESSION_NODE_BITS) - 1;
        for (level, slot, node) in [(0.0, 0u8, 0u32), (123.5, 7, 42), (-4.5, 255, max_node)] {
            let key = pack_session_entry(level, slot, node);
            assert_eq!(session_entry_slot(key), slot);
            assert_eq!(session_entry_node(key), node);
            assert_eq!(session_entry_width(key), 1);
        }
        // CP priority dominates regardless of which session an entry
        // belongs to — the cross-session CP-first rule
        assert!(pack_session_entry(9.0, 0, 5) > pack_session_entry(5.0, 200, 1));
        // level field is layout-compatible with the single-graph packing
        assert_eq!(
            pack_session_entry(42.0, 3, 9) >> 32,
            pack_entry(42.0, 9) >> 32,
        );
        // quantize-equal levels tie-break by (slot, node), deterministically
        assert!(pack_session_entry(7.0, 2, 0) > pack_session_entry(7.0, 1, 99));
        assert!(pack_session_entry(7.0, 1, 9) > pack_session_entry(7.0, 1, 8));
    }

    #[test]
    fn wide_session_entry_roundtrips_and_width_one_matches_legacy_layout() {
        let max_node = (1 << SESSION_NODE_BITS) - 1;
        for (level, slot, node) in [(0.0, 0u8, 0u32), (123.5, 7, 42), (-4.5, 255, max_node)] {
            for width in [1u32, 2, 5, MAX_WIDTH] {
                let key = pack_session_entry_wide(level, slot, node, width);
                assert_eq!(session_entry_slot(key), slot);
                assert_eq!(session_entry_node(key), node);
                assert_eq!(session_entry_width(key), width);
                assert_eq!(
                    key >> ENTRY_LEVEL_BITS,
                    pack_session_entry(level, slot, node) >> ENTRY_LEVEL_BITS,
                    "width field must never disturb the CP-level half"
                );
            }
            // w = 1 reproduces the pre-moldable [level:32|slot:8|node:24]
            // layout bit for bit (the slot shift is unchanged at 24 and
            // the width bits are zero) for every node below 2^20
            let legacy = ((quantize(level) as u64) << 32) | ((slot as u64) << 24) | node as u64;
            assert_eq!(pack_session_entry(level, slot, node), legacy);
        }
    }

    #[test]
    fn packed_key_ties_prefer_earlier_seq() {
        let a = pack(7.0, 0);
        let b = pack(7.0, 1);
        assert!(a > b, "earlier seq must win the max-compare on equal priority");
        assert!(pack(8.0, 9) > pack(7.0, 0), "priority dominates seq");
    }

    #[test]
    fn cp_first_pops_highest_level() {
        let mut r = ReadySet::new(Policy::CriticalPathFirst, vec![5.0, 50.0, 10.0], 0);
        r.push(0);
        r.push(1);
        r.push(2);
        assert_eq!(r.pop(), Some(1));
        assert_eq!(r.pop(), Some(2));
        assert_eq!(r.pop(), Some(0));
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn cp_first_ties_are_fifo() {
        let mut r = ReadySet::new(Policy::CriticalPathFirst, vec![5.0, 5.0, 5.0], 0);
        r.push(2);
        r.push(0);
        r.push(1);
        assert_eq!(r.pop(), Some(2));
        assert_eq!(r.pop(), Some(0));
        assert_eq!(r.pop(), Some(1));
    }

    #[test]
    fn quantization_bucket_collapses_to_fifo() {
        // two levels that differ only below the top 32 bits of the
        // total-order map land in one bucket and must dispatch FIFO —
        // the documented trade of the packed key
        let a = 1e6f64;
        let b = f64::from_bits(a.to_bits() + 1); // next representable, b > a
        assert!(b > a);
        assert_eq!(quantize(a), quantize(b), "test premise: same bucket");
        // node 0 has the *higher* level (b) but is pushed second
        let mut r = ReadySet::new(Policy::CriticalPathFirst, vec![b, a], 0);
        r.push(1);
        r.push(0);
        assert_eq!(r.pop(), Some(1), "within a bucket, push order wins");
        assert_eq!(r.pop(), Some(0));
        // and a clearly distinct level still dominates the bucket
        let mut r = ReadySet::new(Policy::CriticalPathFirst, vec![b, a, 2e6], 0);
        r.push(0);
        r.push(1);
        r.push(2);
        assert_eq!(r.pop(), Some(2));
        assert_eq!(r.pop(), Some(0));
        assert_eq!(r.pop(), Some(1));
    }

    #[test]
    fn cp_first_ties_fifo_across_drain_cycles() {
        // the seq counter resets when the set drains; FIFO must still hold
        // within each cycle
        let mut r = ReadySet::new(Policy::CriticalPathFirst, vec![1.0; 6], 0);
        r.push(3);
        r.push(4);
        assert_eq!(r.pop(), Some(3));
        assert_eq!(r.pop(), Some(4));
        r.push(5);
        r.push(0);
        r.push(1);
        assert_eq!(r.pop(), Some(5));
        assert_eq!(r.pop(), Some(0));
        assert_eq!(r.pop(), Some(1));
    }

    #[test]
    fn heap_handles_interleaved_push_pop() {
        let levels: Vec<f64> = (0..32).map(|i| (i % 7) as f64).collect();
        let mut r = ReadySet::new(Policy::CriticalPathFirst, levels.clone(), 0);
        r.push(0);
        r.push(8);
        r.push(13);
        assert_eq!(r.pop(), Some(13)); // level 6 highest
        r.push(20); // level 6
        r.push(6); // level 6, later
        assert_eq!(r.pop(), Some(20));
        assert_eq!(r.pop(), Some(6));
        assert_eq!(r.pop(), Some(8)); // level 1
        assert_eq!(r.pop(), Some(0)); // level 0
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn anti_critical_is_reverse() {
        let mut r = ReadySet::new(Policy::AntiCritical, vec![5.0, 50.0, 10.0], 0);
        r.push(0);
        r.push(1);
        r.push(2);
        assert_eq!(r.pop(), Some(0));
        assert_eq!(r.pop(), Some(2));
        assert_eq!(r.pop(), Some(1));
    }

    #[test]
    fn fifo_and_lifo() {
        let mut f = ReadySet::new(Policy::Fifo, vec![0.0; 3], 0);
        f.push(0);
        f.push(1);
        assert_eq!(f.pop(), Some(0));
        let mut l = ReadySet::new(Policy::Lifo, vec![0.0; 3], 0);
        l.push(0);
        l.push(1);
        assert_eq!(l.pop(), Some(1));
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let run = |seed| {
            let mut r = ReadySet::new(Policy::Random, vec![0.0; 10], seed);
            for i in 0..10 {
                r.push(i);
            }
            let mut out = Vec::new();
            while let Some(n) = r.pop() {
                out.push(n);
            }
            out
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn len_tracking() {
        let mut r = ReadySet::new(Policy::Fifo, vec![0.0; 4], 0);
        assert!(r.is_empty());
        r.push(0);
        r.push(1);
        assert_eq!(r.len(), 2);
        r.pop();
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn accepts_borrowed_levels() {
        let levels = [3.0f64, 1.0, 2.0];
        let mut r = ReadySet::new(Policy::CriticalPathFirst, &levels[..], 0);
        r.push(0);
        r.push(1);
        r.push(2);
        assert_eq!(r.pop(), Some(0));
        assert_eq!(r.pop(), Some(2));
        assert_eq!(r.pop(), Some(1));
    }
}
