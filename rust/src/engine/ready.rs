//! Dependency tracking and the ready-operation set.
//!
//! `DepTracker` owns the per-node remaining-dependency counts (the
//! "triggering" in Algorithm 2); `ReadySet` owns the ordering of ready ops
//! under a [`Policy`] (the max binary heap of §5.2 for critical-path-first).
//! Both are shared by every engine — simulated and threaded — so the data
//! structures being benchmarked are the ones actually scheduling.

use std::collections::{BinaryHeap, VecDeque};

use crate::graph::{Graph, NodeId};
use crate::util::rng::Rng;

use super::policies::Policy;

/// Remaining-dependency counters.
#[derive(Debug, Clone)]
pub struct DepTracker {
    indegree: Vec<u32>,
    remaining: usize,
}

impl DepTracker {
    pub fn new(graph: &Graph) -> DepTracker {
        let indegree: Vec<u32> = (0..graph.len() as NodeId)
            .map(|v| graph.in_degree(v) as u32)
            .collect();
        DepTracker { indegree, remaining: graph.len() }
    }

    /// Nodes with no dependencies (call once at start).
    pub fn sources(&self) -> Vec<NodeId> {
        self.indegree
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i as NodeId)
            .collect()
    }

    /// Mark `node` executed; invoke `on_ready` for each newly-triggered op.
    pub fn complete(&mut self, graph: &Graph, node: NodeId, mut on_ready: impl FnMut(NodeId)) {
        debug_assert!(self.remaining > 0);
        self.remaining -= 1;
        for &s in graph.succs(node) {
            let d = &mut self.indegree[s as usize];
            debug_assert!(*d > 0, "double trigger of node {s}");
            *d -= 1;
            if *d == 0 {
                on_ready(s);
            }
        }
    }

    /// Ops not yet executed.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    pub fn is_done(&self) -> bool {
        self.remaining == 0
    }
}

#[derive(Debug)]
struct HeapEntry {
    priority: f64,
    seq: u64,
    node: NodeId,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // max-heap on priority; FIFO (smaller seq first) on ties
        self.priority
            .total_cmp(&other.priority)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The set of ready-to-run operations, ordered by policy.
#[derive(Debug)]
pub struct ReadySet {
    policy: Policy,
    levels: Vec<f64>,
    heap: BinaryHeap<HeapEntry>,
    queue: VecDeque<NodeId>,
    stack: Vec<NodeId>,
    rng: Rng,
    seq: u64,
    len: usize,
}

impl ReadySet {
    /// `levels` supplies priorities for the level-based policies; pass the
    /// output of [`crate::graph::levels`] (or unit estimates).
    pub fn new(policy: Policy, levels: Vec<f64>, seed: u64) -> ReadySet {
        ReadySet {
            policy,
            levels,
            heap: BinaryHeap::new(),
            queue: VecDeque::new(),
            stack: Vec::new(),
            rng: Rng::new(seed),
            seq: 0,
            len: 0,
        }
    }

    pub fn push(&mut self, node: NodeId) {
        self.len += 1;
        match self.policy {
            Policy::CriticalPathFirst => {
                let priority = self.levels[node as usize];
                self.heap.push(HeapEntry { priority, seq: self.seq, node });
            }
            Policy::AntiCritical => {
                let priority = -self.levels[node as usize];
                self.heap.push(HeapEntry { priority, seq: self.seq, node });
            }
            Policy::Fifo => self.queue.push_back(node),
            Policy::Lifo => self.stack.push(node),
            Policy::Random => self.stack.push(node),
        }
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<NodeId> {
        let out = match self.policy {
            Policy::CriticalPathFirst | Policy::AntiCritical => self.heap.pop().map(|e| e.node),
            Policy::Fifo => self.queue.pop_front(),
            Policy::Lifo => self.stack.pop(),
            Policy::Random => {
                if self.stack.is_empty() {
                    None
                } else {
                    let i = self.rng.range(0, self.stack.len());
                    Some(self.stack.swap_remove(i))
                }
            }
        };
        if out.is_some() {
            self.len -= 1;
        }
        out
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::OpKind;
    use crate::graph::GraphBuilder;

    #[test]
    fn dep_tracker_triggers_in_order() {
        let mut b = GraphBuilder::new();
        let a = b.add("a", OpKind::Scalar);
        let c = b.add("c", OpKind::Scalar);
        let d = b.add_after("d", OpKind::Scalar, &[a, c]);
        let g = b.build().unwrap();
        let mut t = DepTracker::new(&g);
        assert_eq!(t.sources(), vec![a, c]);
        let mut fired = Vec::new();
        t.complete(&g, a, |n| fired.push(n));
        assert!(fired.is_empty(), "d still blocked on c");
        t.complete(&g, c, |n| fired.push(n));
        assert_eq!(fired, vec![d]);
        t.complete(&g, d, |_| {});
        assert!(t.is_done());
    }

    #[test]
    fn cp_first_pops_highest_level() {
        let mut r = ReadySet::new(Policy::CriticalPathFirst, vec![5.0, 50.0, 10.0], 0);
        r.push(0);
        r.push(1);
        r.push(2);
        assert_eq!(r.pop(), Some(1));
        assert_eq!(r.pop(), Some(2));
        assert_eq!(r.pop(), Some(0));
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn cp_first_ties_are_fifo() {
        let mut r = ReadySet::new(Policy::CriticalPathFirst, vec![5.0, 5.0, 5.0], 0);
        r.push(2);
        r.push(0);
        r.push(1);
        assert_eq!(r.pop(), Some(2));
        assert_eq!(r.pop(), Some(0));
        assert_eq!(r.pop(), Some(1));
    }

    #[test]
    fn anti_critical_is_reverse() {
        let mut r = ReadySet::new(Policy::AntiCritical, vec![5.0, 50.0, 10.0], 0);
        r.push(0);
        r.push(1);
        r.push(2);
        assert_eq!(r.pop(), Some(0));
        assert_eq!(r.pop(), Some(2));
        assert_eq!(r.pop(), Some(1));
    }

    #[test]
    fn fifo_and_lifo() {
        let mut f = ReadySet::new(Policy::Fifo, vec![0.0; 3], 0);
        f.push(0);
        f.push(1);
        assert_eq!(f.pop(), Some(0));
        let mut l = ReadySet::new(Policy::Lifo, vec![0.0; 3], 0);
        l.push(0);
        l.push(1);
        assert_eq!(l.pop(), Some(1));
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let run = |seed| {
            let mut r = ReadySet::new(Policy::Random, vec![0.0; 10], seed);
            for i in 0..10 {
                r.push(i);
            }
            let mut out = Vec::new();
            while let Some(n) = r.pop() {
                out.push(n);
            }
            out
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }

    #[test]
    fn len_tracking() {
        let mut r = ReadySet::new(Policy::Fifo, vec![0.0; 4], 0);
        assert!(r.is_empty());
        r.push(0);
        r.push(1);
        assert_eq!(r.len(), 2);
        r.pop();
        assert_eq!(r.len(), 1);
    }
}
