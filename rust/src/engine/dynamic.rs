//! Dynamic executor-count engine — the §6 optimization the paper tried
//! and rejected, implemented for real (not just priced analytically).
//!
//! > "We considered varying the number of executors dynamically … For
//! > example, we tried to use different numbers of executors for forward
//! > and backward computations … typically the number of parallel
//! > operations doubles during the backward pass. … the overhead of
//! > context switches between different threads on the manycore CPU is
//! > significant, at about 10-30 ms."
//!
//! The engine runs the forward phase with one fleet; once every forward op
//! has completed and the workers have drained, it pays the OpenMP
//! team-reconfiguration cost and continues the backward phase with the
//! second fleet. The ablation bench reproduces the paper's conclusion:
//! the reconfiguration cost swamps the gain from extra backward
//! parallelism.

use crate::graph::{levels, Graph, NodeId};
use crate::sim::{BandwidthArbiter, EventQueue};

use super::policies::Policy;
use super::ready::{DepTracker, ReadySet};
use super::scheduler::IdleBitmap;
use super::trace::{OpRecord, LIGHTWEIGHT_EXECUTOR};
use super::{Engine, EngineMetrics, RunResult, SimEnv};

/// Is this node part of the backward pass? The autodiff tape
/// ([`crate::models::common`]) names gradient/update ops with these
/// suffixes.
pub fn is_backward_op(name: &str) -> bool {
    name.ends_with(".dgrad")
        || name.ends_with(".wgrad")
        || name.ends_with(".sgd")
        || name == "loss.grad_seed"
}

/// Two-phase fleet configuration.
#[derive(Debug, Clone)]
pub struct DynamicFleetEngine {
    /// Forward-phase fleet `(executors, threads_per)`.
    pub fwd: (usize, usize),
    /// Backward-phase fleet (typically 2× the executors at half the team).
    pub bwd: (usize, usize),
}

impl DynamicFleetEngine {
    pub fn new(fwd: (usize, usize), bwd: (usize, usize)) -> DynamicFleetEngine {
        DynamicFleetEngine { fwd, bwd }
    }
}

enum Ev {
    /// A worker-executor op finished.
    Done { node: NodeId, exec: usize, bw_token: u64 },
    /// A light-weight-executor op finished.
    DoneLw { node: NodeId },
    /// The OpenMP team reconfiguration completed.
    ResizeDone,
}

impl Engine for DynamicFleetEngine {
    fn name(&self) -> String {
        format!("dynamic-{}x{}-to-{}x{}", self.fwd.0, self.fwd.1, self.bwd.0, self.bwd.1)
    }

    fn run(&self, graph: &Graph, env: &SimEnv) -> RunResult {
        let cost = &env.cost;
        let interference = env.interference();
        let mut rng = env.rng();
        let max_exec = self.fwd.0.max(self.bwd.0);

        let backward: Vec<bool> =
            graph.nodes().iter().map(|n| is_backward_op(&n.name)).collect();
        let fwd_total = backward.iter().filter(|&&b| !b).count();
        let dur_fwd: Vec<f64> = graph
            .nodes()
            .iter()
            .map(|n| cost.duration_us(&n.kind, self.fwd.1))
            .collect();
        let dur_bwd: Vec<f64> = graph
            .nodes()
            .iter()
            .map(|n| cost.duration_us(&n.kind, self.bwd.1))
            .collect();
        let level_values = levels(graph, &dur_fwd);

        let mut deps = DepTracker::new(graph);
        let mut ready = ReadySet::new(Policy::CriticalPathFirst, level_values, env.seed);
        let mut idle = IdleBitmap::new(max_exec);
        for e in self.fwd.0..max_exec {
            idle.set_busy(e); // slots closed during the forward phase
        }

        let mut q: EventQueue<Ev> = EventQueue::new();
        let mut bw = BandwidthArbiter::new(cost.machine.mcdram_bw);
        let mut records = Vec::with_capacity(graph.len());
        let mut metrics = EngineMetrics {
            executor_busy_us: vec![0.0; max_exec],
            ..Default::default()
        };
        let mut sched_free = 0.0f64;
        let mut lw_free = 0.0f64;
        let mut inflight = 0usize;
        let mut fwd_done = 0usize;
        let mut in_backward = false;
        let mut resizing = false;
        let mut resize_requested = false;

        macro_rules! dispatch {
            ($now:expr) => {
                if !resizing {
                    while !ready.is_empty() {
                        // peek routing: tiny ops go to the LW lane even when
                        // workers are saturated
                        if !idle.any_idle() {
                            break;
                        }
                        let node = ready.pop().unwrap();
                        let kind = &graph.node(node).kind;
                        if kind.is_tiny() {
                            let start = lw_free.max($now);
                            let dur = cost.cal.tiny_op_us * interference.noise(&mut rng);
                            lw_free = start + dur;
                            metrics.lightweight_ops += 1;
                            records.push(OpRecord {
                                node,
                                executor: LIGHTWEIGHT_EXECUTOR,
                                start_us: start,
                                end_us: start + dur,
                            });
                            q.schedule(start + dur, Ev::DoneLw { node });
                            continue;
                        }
                        let e = idle.first_idle().unwrap();
                        idle.set_busy(e);
                        inflight += 1;
                        sched_free = sched_free.max($now) + interference.graphi_dispatch_us();
                        metrics.dispatches += 1;
                        let start = sched_free;
                        let base = if in_backward { dur_bwd[node as usize] } else { dur_fwd[node as usize] };
                        let mut dur = base * interference.noise(&mut rng);
                        let (stretch, token) = bw.admit(kind.bytes() / (base * 1e-6).max(1e-12));
                        dur *= stretch;
                        metrics.executor_busy_us[e] += dur;
                        records.push(OpRecord { node, executor: e as u32, start_us: start, end_us: start + dur });
                        q.schedule(start + dur, Ev::Done { node, exec: e, bw_token: token });
                    }
                }
            };
        }

        macro_rules! complete {
            ($node:expr, $t:expr) => {
                if !backward[$node as usize] {
                    fwd_done += 1;
                    if fwd_done == fwd_total {
                        resize_requested = true;
                    }
                }
                deps.complete(graph, $node, |n| ready.push(n));
            };
        }

        for s in deps.sources() {
            ready.push(s);
        }
        dispatch!(0.0);
        let mut makespan = 0.0f64;
        while let Some((t, ev)) = q.pop() {
            makespan = makespan.max(t);
            match ev {
                Ev::Done { node, exec, bw_token } => {
                    idle.set_idle(exec);
                    bw.release(bw_token);
                    inflight -= 1;
                    complete!(node, t);
                }
                Ev::DoneLw { node } => {
                    complete!(node, t);
                }
                Ev::ResizeDone => {
                    // open the backward fleet's executor slots
                    for e in 0..max_exec {
                        let open = e < self.bwd.0;
                        if open && !idle.is_idle(e) {
                            idle.set_idle(e);
                        } else if !open && idle.is_idle(e) {
                            idle.set_busy(e);
                        }
                    }
                    in_backward = true;
                    resizing = false;
                    sched_free = sched_free.max(t);
                }
            }
            // initiate the reconfiguration once forward work has drained
            if resize_requested && !in_backward && !resizing && inflight == 0 {
                resizing = true;
                resize_requested = false;
                metrics.contention_us += interference.team_resize_us();
                q.schedule(t + interference.team_resize_us(), Ev::ResizeDone);
            }
            dispatch!(t);
        }
        assert!(deps.is_done(), "dynamic engine drained with unexecuted ops");
        let result = RunResult { makespan_us: makespan, records, metrics };
        debug_assert!(result.validate(graph).is_ok(), "{:?}", result.validate(graph));
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::GraphiEngine;
    use crate::models::{self, ModelKind, ModelSize};

    #[test]
    fn produces_valid_schedule() {
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let env = SimEnv::knl_deterministic();
        let r = DynamicFleetEngine::new((8, 8), (16, 4)).run(&g, &env);
        r.validate(&g).unwrap();
        assert_eq!(r.records.len(), g.len());
    }

    #[test]
    fn resize_cost_makes_dynamic_lose_to_static() {
        // the §6 conclusion: two team reconfigurations per iteration are
        // worth more than the backward-parallelism gain
        let g = models::build(ModelKind::Lstm, ModelSize::Small);
        let env = SimEnv::knl_deterministic();
        let static_best = GraphiEngine::new(8, 8).run(&g, &env).makespan_us;
        let dynamic = DynamicFleetEngine::new((8, 8), (16, 4)).run(&g, &env).makespan_us;
        assert!(
            dynamic > static_best,
            "dynamic {dynamic} should lose to static {static_best}"
        );
        // and the loss should be at least on the order of the resize cost
        assert!(dynamic - static_best > 10_000.0, "gap {}", dynamic - static_best);
    }

    #[test]
    fn backward_classifier() {
        assert!(is_backward_op("t3.l1.gemm.dgrad"));
        assert!(is_backward_op("head.proj.wgrad"));
        assert!(is_backward_op("l0.m2.conv.sgd"));
        assert!(is_backward_op("loss.grad_seed"));
        assert!(!is_backward_op("t3.l1.gemm"));
        assert!(!is_backward_op("head.softmax"));
    }

    #[test]
    fn phase_counts_cover_graph() {
        let g = models::build(ModelKind::PathNet, ModelSize::Small);
        let bwd = g.nodes().iter().filter(|n| is_backward_op(&n.name)).count();
        let fwd = g.len() - bwd;
        assert!(fwd > 0 && bwd > 0);
        // backward ≈ fwd-grad + weight-grads + sgd: at least half as many
        assert!(bwd * 2 > fwd, "bwd {bwd} fwd {fwd}");
    }
}
