//! Differential + acceptance suite for persistent fleets and multi-graph
//! serving sessions (PR 5):
//!
//! 1. **Spawned-once fleets**: ≥8 sequential sessions on one fleet never
//!    grow the executor thread count past the fleet size, and
//!    `ThreadedGraphi::run`'s public counters survive on top of the
//!    session core.
//! 2. **Concurrent-vs-solo differential**: one fleet running sessions A
//!    and B concurrently produces, per session, the same op *set* and a
//!    dependency-valid order as running each alone — in both dispatch
//!    modes — and the per-session metric sums partition the fleet totals.
//! 3. **Admission**: a session whose planned §5.1 footprint exceeds the
//!    remaining budget waits until the budget frees.
//! 4. **Sim mirror agreement**: `GraphiEngine::run_concurrent` (N DAGs on
//!    one virtual fleet) and the threaded fleet agree on per-session op
//!    sets and produce dependency-valid per-session orders on random DAG
//!    pairs, both modes.
//! 5. **Fault domains (PR 6)**: an op panic is confined to its session —
//!    concurrent and subsequent sessions on the same fleet complete with
//!    exactly-once semantics, and `Fleet::shutdown` reports the fault as
//!    an error value instead of aborting.

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

use graphi::engine::{DispatchMode, GraphiEngine, SimArrival, SimEnv, SimSessionOutcome};
use graphi::graph::op::{EwKind, OpKind};
use graphi::graph::{Graph, GraphBuilder, NodeId};
use graphi::runtime::{
    Fleet, FleetConfig, SessionError, SessionQueue, SessionReport, ThreadedGraphi,
};
use graphi::util::testkit::{check, DagCase, DagGen};

fn unit_levels(g: &Graph) -> Vec<f64> {
    vec![1.0; g.len()]
}

/// A moderately wide mixed DAG for session tests.
fn mixed_graph(seed: u64) -> Graph {
    let mut b = GraphBuilder::new();
    let src = b.add("src", OpKind::Scalar);
    let mut prev: Vec<NodeId> = vec![src];
    for layer in 0..6 {
        let width = 2 + ((seed as usize + layer) % 3);
        let mut this = Vec::new();
        for i in 0..width {
            let n = b.add(
                format!("l{layer}n{i}"),
                OpKind::Elementwise { n: 1000, arity: 1, kind: EwKind::Arith },
            );
            b.depend(prev[i % prev.len()], n);
            this.push(n);
        }
        prev = this;
    }
    b.add_after("sink", OpKind::Scalar, &prev);
    b.build().unwrap()
}

/// The execution order a session report implies (records are sorted by
/// start time already; re-sort defensively).
fn order_of(report: &SessionReport) -> Vec<NodeId> {
    let mut recs = report.records.clone();
    recs.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
    recs.into_iter().map(|r| r.node).collect()
}

fn sorted_op_set(order: &[NodeId]) -> Vec<NodeId> {
    let mut set = order.to_vec();
    set.sort_unstable();
    set
}

/// Acceptance: fleet threads are spawned once per `Fleet`, not per run —
/// 8 sequential sessions on one fleet, executor thread count pinned, and
/// observed work concurrency never exceeds the fleet size.
#[test]
fn eight_sequential_sessions_reuse_one_fleet_of_threads() {
    let g = mixed_graph(1);
    for mode in DispatchMode::ALL {
        let in_work = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let work = |_n: NodeId| {
            let now = in_work.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::yield_now(); // widen the overlap window
            in_work.fetch_sub(1, Ordering::SeqCst);
        };
        let totals = std::thread::scope(|scope| {
            let fleet = Fleet::new(scope, FleetConfig::new(3).with_dispatch(mode));
            for i in 0..8 {
                let report = fleet
                    .submit(&g, unit_levels(&g), &work)
                    .wait()
                    .expect("healthy session");
                assert_eq!(report.records.len(), g.len(), "{} session {i}", mode.name());
                assert_eq!(report.dispatches, g.len() as u64, "{} session {i}", mode.name());
                assert!(
                    report.records.iter().all(|r| (r.executor as usize) < 3),
                    "{} session {i}: executor id out of fleet range",
                    mode.name()
                );
                // threads are NOT respawned per session
                assert!(
                    fleet.executor_threads_started() <= 3,
                    "{} session {i}: more executor threads than the fleet size",
                    mode.name()
                );
            }
            fleet.shutdown().expect("clean fleet")
        });
        assert_eq!(totals.executor_threads, 3, "{}", mode.name());
        assert_eq!(totals.sessions_completed, 8, "{}", mode.name());
        assert!(
            peak.load(Ordering::SeqCst) <= 3,
            "{}: {} ops ran concurrently on a 3-executor fleet",
            mode.name(),
            peak.load(Ordering::SeqCst)
        );
    }
}

/// `ThreadedGraphi::run` public behavior is preserved on top of the
/// session core: same counters, across repeated runs of one engine value.
#[test]
fn threaded_run_counters_survive_the_session_core() {
    let g = mixed_graph(2);
    for mode in DispatchMode::ALL {
        let engine = ThreadedGraphi::new(2).with_dispatch(mode);
        for _ in 0..3 {
            let counter = AtomicU64::new(0);
            let r = engine
                .run(&g, unit_levels(&g), |_| {
                    counter.fetch_add(1, Ordering::Relaxed);
                })
                .unwrap();
            assert_eq!(counter.load(Ordering::Relaxed), g.len() as u64, "{}", mode.name());
            assert_eq!(r.records.len(), g.len(), "{}", mode.name());
            assert_eq!(r.dispatches, g.len() as u64, "{}", mode.name());
            assert!(r.steals <= r.dispatches, "{}", mode.name());
            assert_eq!(r.cross_domain_steals, 0, "{}: flat fleet", mode.name());
            assert_eq!(r.mode_switches, 0, "{}", mode.name());
            assert!(r.wall_us > 0.0, "{}", mode.name());
        }
    }
}

/// Differential: sessions A and B concurrently on one fleet produce, per
/// session, the same op set and a dependency-valid order as each alone;
/// per-session metric sums partition the fleet totals.
#[test]
fn concurrent_sessions_match_solo_semantics_in_both_modes() {
    let a = mixed_graph(3);
    let b = mixed_graph(7);
    for mode in DispatchMode::ALL {
        let work = |_n: NodeId| {};
        // solo baselines, one fleet each
        let solo = |g: &Graph| {
            std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(4).with_dispatch(mode));
                let report =
                    fleet.submit(g, unit_levels(g), &work).wait().expect("healthy session");
                fleet.shutdown().expect("clean fleet");
                report
            })
        };
        let solo_a = solo(&a);
        let solo_b = solo(&b);
        g_validate(&a, &order_of(&solo_a), mode, "solo A");
        g_validate(&b, &order_of(&solo_b), mode, "solo B");
        // concurrent: both submitted before either wait
        let (rep_a, rep_b, totals) = std::thread::scope(|scope| {
            let fleet = Fleet::new(scope, FleetConfig::new(4).with_dispatch(mode));
            let ha = fleet.submit(&a, unit_levels(&a), &work);
            let hb = fleet.submit(&b, unit_levels(&b), &work);
            let ra = ha.wait().expect("healthy session A");
            let rb = hb.wait().expect("healthy session B");
            let totals = fleet.shutdown().expect("clean fleet");
            (ra, rb, totals)
        });
        let order_a = order_of(&rep_a);
        let order_b = order_of(&rep_b);
        // same op set as solo, dependency-valid order per session
        assert_eq!(sorted_op_set(&order_a), sorted_op_set(&order_of(&solo_a)), "{}", mode.name());
        assert_eq!(sorted_op_set(&order_b), sorted_op_set(&order_of(&solo_b)), "{}", mode.name());
        g_validate(&a, &order_a, mode, "concurrent A");
        g_validate(&b, &order_b, mode, "concurrent B");
        // metric partition: every dispatch/steal belongs to one session
        assert_eq!(
            rep_a.dispatches + rep_b.dispatches,
            totals.dispatches,
            "{}",
            mode.name()
        );
        assert!(
            rep_a.steals + rep_b.steals <= totals.steals,
            "{}: session steals exceed the fleet total",
            mode.name()
        );
        assert_eq!(totals.sessions_completed, 2, "{}", mode.name());
    }
}

fn g_validate(g: &Graph, order: &[NodeId], mode: DispatchMode, tag: &str) {
    g.validate_order(order)
        .unwrap_or_else(|e| panic!("{} {tag}: {e}", mode.name()));
}

/// Admission: an over-budget session waits until the budget frees —
/// end-to-end through a fleet, not just the queue unit tests.
#[test]
fn over_budget_session_waits_for_admission() {
    let g = mixed_graph(5);
    let queue = SessionQueue::new(1000);
    let started_b = AtomicU32::new(0);
    let work = |_n: NodeId| {};
    std::thread::scope(|scope| {
        let fleet = Fleet::new(scope, FleetConfig::new(2));
        let fleet_ref = &fleet;
        let permit_a = queue.admit(900);
        let ha = fleet_ref.submit(&g, unit_levels(&g), &work);
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::scope(|inner| {
            let queue = &queue;
            let started_b = &started_b;
            let g = &g;
            let work = &work;
            inner.spawn(move || {
                // B needs 400 of a 1000-byte budget with 900 in use: must
                // block until A's permit drops
                let permit_b = queue.admit(400);
                started_b.store(1, Ordering::SeqCst);
                let hb = fleet_ref.submit(g, unit_levels(g), work);
                let rb = hb.wait().expect("healthy session B");
                drop(permit_b);
                tx.send(rb.records.len()).unwrap();
            });
            assert!(
                rx.recv_timeout(Duration::from_millis(100)).is_err(),
                "over-budget session was admitted while the budget was full"
            );
            assert_eq!(started_b.load(Ordering::SeqCst), 0, "B must still be waiting");
            let ra = ha.wait().expect("healthy session A");
            assert_eq!(ra.records.len(), g.len());
            drop(permit_a);
            let b_records = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(b_records, g.len());
        });
        fleet.shutdown().expect("clean fleet");
    });
}

/// PR 6 acceptance: a session whose op panics reports
/// `SessionError::OpPanicked`; a concurrent healthy session on the same
/// fleet still completes with exactly-once, dependency-valid semantics;
/// the fleet keeps serving afterwards; and `Fleet::shutdown` after the
/// fault returns an error value instead of aborting. Both dispatch modes.
#[test]
fn faulty_session_is_confined_while_concurrent_session_completes() {
    let faulty_graph = mixed_graph(4);
    let healthy_graph = mixed_graph(9);
    let boom = (faulty_graph.len() / 2) as NodeId;
    for mode in DispatchMode::ALL {
        let healthy_runs = AtomicU64::new(0);
        let faulty_work = move |n: NodeId| {
            if n == boom {
                panic!("injected fault at node {n}");
            }
        };
        let healthy_work = |_n: NodeId| {
            healthy_runs.fetch_add(1, Ordering::Relaxed);
        };
        let err = std::thread::scope(|scope| {
            let fleet = Fleet::new(scope, FleetConfig::new(3).with_dispatch(mode));
            let hf = fleet.submit(&faulty_graph, unit_levels(&faulty_graph), &faulty_work);
            let hh = fleet.submit(&healthy_graph, unit_levels(&healthy_graph), &healthy_work);
            let fault = hf.wait().expect_err("panicking session must not report a makespan");
            match &fault {
                SessionError::OpPanicked { node, payload } => {
                    assert_eq!(*node, boom, "{}", mode.name());
                    assert!(payload.contains("injected fault"), "{}: {payload}", mode.name());
                }
                other => panic!("{}: expected OpPanicked, got {other:?}", mode.name()),
            }
            let healthy = hh.wait().expect("concurrent healthy session must complete");
            g_validate(&healthy_graph, &order_of(&healthy), mode, "healthy-during-fault");
            assert_eq!(healthy.records.len(), healthy_graph.len(), "{}", mode.name());
            // the fleet keeps serving after the fault
            let after = fleet
                .submit(&healthy_graph, unit_levels(&healthy_graph), &healthy_work)
                .wait()
                .expect("post-fault session must complete");
            assert_eq!(after.records.len(), healthy_graph.len(), "{}", mode.name());
            fleet.shutdown().expect_err("shutdown after a session fault must report it")
        });
        assert_eq!(err.sessions_failed, 1, "{}", mode.name());
        assert!(
            err.panicked_threads.is_empty(),
            "{}: executors must survive op panics",
            mode.name()
        );
        assert_eq!(err.totals.sessions_completed, 2, "{}", mode.name());
        // exactly-once across both healthy sessions
        assert_eq!(
            healthy_runs.load(Ordering::Relaxed),
            2 * healthy_graph.len() as u64,
            "{}",
            mode.name()
        );
    }
}

fn graph_of(case: &DagCase) -> Graph {
    let mut b = GraphBuilder::new();
    for i in 0..case.n {
        let kind = match i % 3 {
            0 => OpKind::MatMul { m: 16, k: 32 + (case.weights[i] as u64 % 64), n: 32 },
            1 => OpKind::Elementwise {
                n: 1_000 + (case.weights[i] * 100.0) as u64,
                arity: 2,
                kind: EwKind::Arith,
            },
            _ => OpKind::Scalar,
        };
        b.add(format!("n{i}"), kind);
    }
    for &(src, dst) in &case.edges {
        b.depend(src, dst);
    }
    b.build().expect("testkit DAGs are acyclic by construction")
}

/// A second graph derived from the same case: reversed weights and a
/// shifted op-kind pattern, so the pair is genuinely heterogeneous.
fn sibling_graph_of(case: &DagCase) -> Graph {
    let mut b = GraphBuilder::new();
    for i in 0..case.n {
        let w = case.weights[case.n - 1 - i];
        let kind = match i % 2 {
            0 => OpKind::Elementwise { n: 500 + (w * 50.0) as u64, arity: 1, kind: EwKind::Arith },
            _ => OpKind::Scalar,
        };
        b.add(format!("m{i}"), kind);
    }
    for &(src, dst) in &case.edges {
        b.depend(src, dst);
    }
    b.build().expect("testkit DAGs are acyclic by construction")
}

/// PR 8 tentpole acceptance: the threaded serving frontier
/// (`Fleet` + `SessionQueue`) and the simulator's open-loop mirror
/// (`GraphiEngine::run_open_loop`) put every request of a seeded arrival
/// trace into the **same outcome class** — Completed / Shed /
/// DeadlineExceeded — under every admission policy and both dispatch
/// modes.
///
/// The trace is engineered with tens-of-milliseconds margins around every
/// decision point so real-thread scheduling jitter cannot flip a class:
///
/// * request 0 takes the whole budget and holds it ~300 ms  → Completed
/// * request 1 arrives under the holder with zero patience  → Shed
/// * requests 2–3 fit together once the holder quiesces     → Completed
/// * request 4 needs the whole budget again but carries a
///   1 ms deadline against a 50 ms service time             → DeadlineExceeded
///
/// Threaded service times are work-closure sleeps; the sim replays the
/// identical trace through `service_us` overrides, so the two sides share
/// one ground truth rather than a fitted cost model.
#[test]
fn open_loop_outcome_classes_agree_between_threads_and_sim() {
    use graphi::runtime::{AdmissionPolicy, AdmitRequest};
    use graphi::util::rng::Rng;
    use std::sync::Mutex;

    let g = {
        let mut b = GraphBuilder::new();
        b.add("op", OpKind::Scalar);
        b.build().unwrap()
    };
    // the deadline request runs a 2-op chain: the fleet checks deadlines
    // cooperatively at pop time, so op 0's sleep must push op 1's pop past
    // the deadline for the threads to observe the miss
    let g_chain = {
        let mut b = GraphBuilder::new();
        let a = b.add("op0", OpKind::Scalar);
        let z = b.add("op1", OpKind::Scalar);
        b.depend(a, z);
        b.build().unwrap()
    };

    // seeded arrivals: fixed 40 ms spacing plus < 8 ms of seeded jitter
    // (gaps stay positive, so the trace stays in ticket order)
    let mut rng = Rng::new(0xA881_0008);
    let at: Vec<f64> =
        [0.0, 40_000.0, 80_000.0, 120_000.0, 160_000.0]
            .iter()
            .map(|base| base + rng.below(8_000) as f64)
            .collect();
    let trace = vec![
        SimArrival { at_us: at[0], bytes: 100, service_us: Some(300_000.0), ..Default::default() },
        SimArrival {
            at_us: at[1],
            bytes: 100,
            patience_us: Some(0.0),
            service_us: Some(10_000.0),
            ..Default::default()
        },
        SimArrival { at_us: at[2], bytes: 50, service_us: Some(20_000.0), ..Default::default() },
        SimArrival { at_us: at[3], bytes: 50, service_us: Some(20_000.0), ..Default::default() },
        SimArrival {
            at_us: at[4],
            bytes: 100,
            deadline_us: Some(1_000.0),
            service_us: Some(50_000.0),
            ..Default::default()
        },
    ];
    let graphs: Vec<&Graph> = (0..trace.len()).map(|i| if i == 4 { &g_chain } else { &g }).collect();
    // per-request work closures built before the fleet scope so their
    // borrows outlive every session; each spreads the trace's service time
    // evenly over its graph's ops, so threads and sim price identically
    let works: Vec<Box<dyn Fn(NodeId) + Send + Sync>> = trace
        .iter()
        .zip(&graphs)
        .map(|(a, g)| {
            let service_us = a.service_us.expect("every trace entry is service-priced");
            let sleep_us = (service_us / g.len() as f64) as u64;
            Box::new(move |_n: NodeId| std::thread::sleep(Duration::from_micros(sleep_us)))
                as Box<dyn Fn(NodeId) + Send + Sync>
        })
        .collect();
    let env = SimEnv::knl_deterministic();

    for mode in DispatchMode::ALL {
        for policy in AdmissionPolicy::ALL {
            let tag = format!("{} {}", mode.name(), policy.name());
            // --- simulator replay ---
            let engine = GraphiEngine::new(2, 8).with_dispatch(mode);
            let sim = engine.run_open_loop(&graphs, &env, &trace, 100, policy);
            let expected: Vec<&str> = sim
                .iter()
                .map(|r| match r.outcome {
                    SimSessionOutcome::Completed => "completed",
                    SimSessionOutcome::Shed => "shed",
                    SimSessionOutcome::DeadlineExceeded => "deadline_missed",
                    ref other => panic!("{tag}: sim produced {other:?} without a fault model"),
                })
                .collect();
            // the engineered margins pin the sim classes exactly
            assert_eq!(
                expected,
                ["completed", "shed", "completed", "completed", "deadline_missed"],
                "{tag}: sim mirror"
            );

            // --- threaded replay of the same trace ---
            let slots: Vec<Mutex<&'static str>> =
                trace.iter().map(|_| Mutex::new("unresolved")).collect();
            let totals = std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(2).with_dispatch(mode));
                let fleet_ref = &fleet;
                let queue = SessionQueue::new(100).with_policy(policy);
                let queue_ref = &queue;
                std::thread::scope(|reqs| {
                    for (i, a) in trace.iter().enumerate() {
                        let slot = &slots[i];
                        let g: &Graph = graphs[i];
                        let work = works[i].as_ref();
                        reqs.spawn(move || {
                            std::thread::sleep(Duration::from_micros(a.at_us as u64));
                            let mut req = AdmitRequest::new(a.bytes).with_class(a.class);
                            if let Some(p) = a.patience_us {
                                req = req.with_patience(Duration::from_micros(p as u64));
                            }
                            let permit = match queue_ref.admit_request(req) {
                                Ok(p) => p,
                                Err(_) => {
                                    fleet_ref.record_shed();
                                    *slot.lock().unwrap() = "shed";
                                    return;
                                }
                            };
                            let handle = match a.deadline_us {
                                Some(d) => fleet_ref.submit_with_deadline(
                                    g,
                                    unit_levels(g),
                                    work,
                                    Duration::from_micros(d as u64),
                                ),
                                None => fleet_ref.submit(g, unit_levels(g), work),
                            };
                            let out = match handle.wait() {
                                Ok(_) => "completed",
                                Err(SessionError::DeadlineExceeded) => "deadline_missed",
                                Err(other) => panic!("unexpected terminal {other:?}"),
                            };
                            drop(permit);
                            *slot.lock().unwrap() = out;
                        });
                    }
                });
                // deadline misses surface through the shutdown error; the
                // totals snapshot is the same either way
                match fleet.shutdown() {
                    Ok(t) => t,
                    Err(e) => e.totals,
                }
            });
            let observed: Vec<&str> =
                slots.iter().map(|s| *s.lock().unwrap()).collect();
            assert_eq!(observed, expected, "{tag}: threads vs sim outcome classes");
            // and the fleet's own 5-class ledger tells the same story
            assert_eq!(totals.sessions_completed, 3, "{tag}");
            assert_eq!(totals.sessions_deadline_missed, 1, "{tag}");
            assert_eq!(totals.sessions_shed, 1, "{tag}");
            assert_eq!(totals.sessions_failed + totals.sessions_cancelled, 0, "{tag}");
        }
    }
}

/// PR 9 tentpole acceptance: with **cross-session dynamic batching** in
/// the loop, the threaded admission frontier (`Batcher` + `SessionQueue`
/// + `Fleet`, replaying the serve loop's leader/follower bookkeeping) and
/// the simulator's `run_open_loop_batched` put every *logical request* of
/// a seeded arrival trace into the same outcome class — under every
/// admission policy and both dispatch modes.
///
/// The trace is engineered with tens-of-milliseconds margins around every
/// batching and admission decision (window 50 ms, services 30–300 ms):
///
/// * requests 0+1 (model G) fill a cap-2 batch on arrival; the union is
///   over budget but admits alone and holds the budget ~300 ms
///                                                  → both Completed
/// * request 2 (model H) cannot join G's group (incompatible), waits out
///   its own window, then sheds on 50 ms patience under the G holder
///                                                  → Shed
/// * request 3 (model H) arrives after request 2's window closed, so it
///   leads a fresh group and is granted when G quiesces
///                                                  → Completed
/// * request 4 (a 2-op chain model) is a singleton leader that pays the
///   full window, then carries a 1 ms deadline against a 50 ms service
///                                                  → DeadlineExceeded
#[test]
fn batched_outcome_classes_agree_between_threads_and_sim() {
    use graphi::runtime::{AdmissionPolicy, AdmitRequest, BatchJoin, BatchMember, Batcher};
    use std::sync::Mutex;
    use std::time::Instant;

    let one_op = |name: &str| {
        let mut b = GraphBuilder::new();
        b.add(name, OpKind::Scalar);
        b.build().unwrap()
    };
    let g = one_op("g");
    let h = one_op("h");
    // deadline model: 2-op chain so the threaded fleet's pop-time deadline
    // check observes the miss after op 0's sleep
    let chain = {
        let mut b = GraphBuilder::new();
        let a = b.add("op0", OpKind::Scalar);
        let z = b.add("op1", OpKind::Scalar);
        b.depend(a, z);
        b.build().unwrap()
    };
    let (g_union, _) = Graph::disjoint_union(&[&g, &g]);

    const WINDOW_US: f64 = 50_000.0;
    const MAX_BATCH: usize = 2;
    let trace = vec![
        SimArrival { at_us: 0.0, bytes: 100, service_us: Some(300_000.0), ..Default::default() },
        SimArrival { at_us: 10_000.0, bytes: 100, service_us: Some(300_000.0), ..Default::default() },
        SimArrival {
            at_us: 60_000.0,
            bytes: 100,
            patience_us: Some(50_000.0),
            service_us: Some(30_000.0),
            ..Default::default()
        },
        SimArrival { at_us: 170_000.0, bytes: 100, service_us: Some(30_000.0), ..Default::default() },
        SimArrival {
            at_us: 250_000.0,
            bytes: 100,
            deadline_us: Some(1_000.0),
            service_us: Some(50_000.0),
            ..Default::default()
        },
    ];
    // model table: request → (batcher slot, graph); sim compatibility is
    // graph pointer identity, threads compatibility is the slot index
    let model: Vec<usize> = vec![0, 0, 1, 1, 2];
    let graphs: Vec<&Graph> =
        model.iter().map(|&m| [&g, &h, &chain][m] as &Graph).collect();
    // per-model work: spread the model's service time over its ops so the
    // threaded fleet and the sim's overrides price identically; union
    // components are copies, so the per-node sleep carries over
    let works: Vec<Box<dyn Fn(NodeId) + Send + Sync>> = [300_000u64, 30_000, 25_000]
        .iter()
        .map(|&sleep_us| {
            Box::new(move |_n: NodeId| std::thread::sleep(Duration::from_micros(sleep_us)))
                as Box<dyn Fn(NodeId) + Send + Sync>
        })
        .collect();
    let env = SimEnv::knl_deterministic();

    for mode in DispatchMode::ALL {
        for policy in AdmissionPolicy::ALL {
            let tag = format!("{} {}", mode.name(), policy.name());
            // --- simulator replay with batching ---
            let engine = GraphiEngine::new(3, 8).with_dispatch(mode);
            let sim = engine.run_open_loop_batched(
                &graphs, &env, &trace, 100, policy, WINDOW_US, MAX_BATCH,
            );
            let expected: Vec<&str> = sim
                .iter()
                .map(|r| match r.outcome {
                    SimSessionOutcome::Completed => "completed",
                    SimSessionOutcome::Shed => "shed",
                    SimSessionOutcome::DeadlineExceeded => "deadline_missed",
                    ref other => panic!("{tag}: sim produced {other:?} without a fault model"),
                })
                .collect();
            assert_eq!(
                expected,
                ["completed", "completed", "shed", "completed", "deadline_missed"],
                "{tag}: sim mirror"
            );
            // batch members resolve together, like a threaded handle.wait()
            assert_eq!(sim[0].makespan_us, sim[1].makespan_us, "{tag}: joint quiescence");

            // --- threaded replay: the real Batcher + queue + fleet ---
            let slots: Vec<Mutex<&'static str>> =
                trace.iter().map(|_| Mutex::new("unresolved")).collect();
            let totals = std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(3).with_dispatch(mode));
                let fleet_ref = &fleet;
                let queue = SessionQueue::new(100).with_policy(policy);
                let queue_ref = &queue;
                let batcher = Batcher::new(3, Duration::from_micros(WINDOW_US as u64));
                let batcher_ref = &batcher;
                std::thread::scope(|reqs| {
                    for (i, a) in trace.iter().enumerate() {
                        let slots = &slots;
                        let trace = &trace;
                        let m = model[i];
                        let graph: &Graph = graphs[i];
                        let union: &Graph = &g_union;
                        let work = works[m].as_ref();
                        reqs.spawn(move || {
                            std::thread::sleep(Duration::from_micros(a.at_us as u64));
                            let member =
                                BatchMember { index: i, class: a.class, t0: Instant::now() };
                            let group = match batcher_ref.join(m, member, MAX_BATCH) {
                                // the leader resolves every member's slot
                                BatchJoin::Follower => return,
                                BatchJoin::Leader(group) => group,
                            };
                            let members = batcher_ref.close(m, &group);
                            // batch = one admission entry: sum bytes, min
                            // class, min patience/deadline — serve's rules
                            let arr = |mm: &BatchMember| &trace[mm.index];
                            let bytes: u64 = members.iter().map(|mm| arr(mm).bytes).sum();
                            let class = members.iter().map(|mm| arr(mm).class).min().unwrap();
                            let patience = members
                                .iter()
                                .filter_map(|mm| arr(mm).patience_us)
                                .fold(None, |acc: Option<f64>, v| {
                                    Some(acc.map_or(v, |a: f64| a.min(v)))
                                });
                            let deadline = members
                                .iter()
                                .filter_map(|mm| arr(mm).deadline_us)
                                .fold(None, |acc: Option<f64>, v| {
                                    Some(acc.map_or(v, |a: f64| a.min(v)))
                                });
                            let mut req = AdmitRequest::new(bytes).with_class(class);
                            if let Some(p) = patience {
                                req = req.with_patience(Duration::from_micros(p as u64));
                            }
                            let permit = match queue_ref.admit_request(req) {
                                Ok(p) => p,
                                Err(_) => {
                                    // a shed fans out to every member
                                    for mm in &members {
                                        fleet_ref.record_shed();
                                        *slots[mm.index].lock().unwrap() = "shed";
                                    }
                                    return;
                                }
                            };
                            let run: &Graph = if members.len() == 2 { union } else { graph };
                            let handle = match deadline {
                                Some(d) => fleet_ref.submit_with_deadline(
                                    run,
                                    unit_levels(run),
                                    work,
                                    Duration::from_micros(d as u64),
                                ),
                                None => fleet_ref.submit(run, unit_levels(run), work),
                            };
                            let out = match handle.wait() {
                                Ok(_) => "completed",
                                Err(SessionError::DeadlineExceeded) => "deadline_missed",
                                Err(other) => panic!("unexpected terminal {other:?}"),
                            };
                            drop(permit);
                            for mm in &members {
                                *slots[mm.index].lock().unwrap() = out;
                            }
                        });
                    }
                });
                match fleet.shutdown() {
                    Ok(t) => t,
                    Err(e) => e.totals,
                }
            });
            let observed: Vec<&str> = slots.iter().map(|s| *s.lock().unwrap()).collect();
            assert_eq!(observed, expected, "{tag}: threads vs sim outcome classes");
            // fleet-session ledger: the 2-way batch is ONE fleet session,
            // so sessions_completed counts 2 (G batch + request 3)
            assert_eq!(totals.sessions_completed, 2, "{tag}");
            assert_eq!(totals.sessions_deadline_missed, 1, "{tag}");
            assert_eq!(totals.sessions_shed, 1, "{tag}");
            assert_eq!(totals.sessions_failed + totals.sessions_cancelled, 0, "{tag}");
        }
    }
}

/// The serve-mode acceptance differential: on random DAG pairs, the sim
/// mirror's multi-graph mode and the threaded fleet agree on per-session
/// op sets, and both produce dependency-valid per-session orders — in
/// both dispatch modes.
#[test]
fn prop_sim_mirror_agrees_with_threaded_fleet_on_random_dag_pairs() {
    let gen = DagGen { max_nodes: 24, edge_prob: 0.15, wmax: 50.0 };
    let env = SimEnv::knl_deterministic();
    check("serve-mode sim/threads agreement", &gen, 12, |case| {
        let g1 = graph_of(case);
        let g2 = sibling_graph_of(case);
        for mode in DispatchMode::ALL {
            // --- simulator: N DAGs on one virtual fleet ---
            let engine = GraphiEngine::new(3, 8).with_dispatch(mode);
            let (union_result, sim_sessions) = engine.run_concurrent(&[&g1, &g2], &env);
            if union_result.records.len() != g1.len() + g2.len() {
                return Err(format!("{}: union record count", mode.name()));
            }
            let mut sim_orders = Vec::new();
            for (g, s) in [(&g1, &sim_sessions[0]), (&g2, &sim_sessions[1])] {
                let mut recs = s.records.clone();
                recs.sort_by(|x, y| x.start_us.total_cmp(&y.start_us));
                let order: Vec<NodeId> = recs.iter().map(|r| r.node).collect();
                g.validate_order(&order)
                    .map_err(|e| format!("{} sim session: {e}", mode.name()))?;
                sim_orders.push(order);
            }
            // --- threaded fleet: same two graphs as concurrent sessions ---
            let work = |_n: NodeId| {};
            let (r1, r2) = std::thread::scope(|scope| {
                let fleet = Fleet::new(scope, FleetConfig::new(3).with_dispatch(mode));
                let h1 = fleet.submit(&g1, unit_levels(&g1), &work);
                let h2 = fleet.submit(&g2, unit_levels(&g2), &work);
                let r1 = h1.wait().expect("healthy session 1");
                let r2 = h2.wait().expect("healthy session 2");
                fleet.shutdown().expect("clean fleet");
                (r1, r2)
            });
            for (g, rep, sim_order) in
                [(&g1, &r1, &sim_orders[0]), (&g2, &r2, &sim_orders[1])]
            {
                let order = order_of(rep);
                g.validate_order(&order)
                    .map_err(|e| format!("{} threaded session: {e}", mode.name()))?;
                // agreement: identical per-session op sets
                if sorted_op_set(&order) != sorted_op_set(sim_order) {
                    return Err(format!("{}: sim and threads disagree on the op set", mode.name()));
                }
            }
        }
        Ok(())
    });
}
