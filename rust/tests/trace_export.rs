//! End-to-end exercise of the session-aware Chrome-trace exporter.
//!
//! Two properties matter beyond the unit tests:
//!
//! 1. a serve run's trace is well-formed observability — one process per
//!    session with named lanes, plus fleet steal/park instants from the
//!    per-executor event sinks;
//! 2. the simulator and the threaded runtime export through the **same
//!    writer** and agree on the op-span sets for the same graphs, so a
//!    sim trace and a real trace of one workload are diffable.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use graphi::cost::CostModel;
use graphi::engine::{
    export_chrome_trace, validate_chrome_trace, DispatchMode, GraphiEngine, SessionTraceExport,
    SimEnv,
};
use graphi::graph::{levels as cp_levels, Graph, NodeId};
use graphi::models::{self, ModelKind, ModelSize};
use graphi::runtime::fleet::{Fleet, FleetConfig};
use graphi::runtime::{serve, ServeConfig};

#[test]
fn serve_trace_exports_sessions_and_fleet_instants() {
    let path = std::env::temp_dir()
        .join(format!("graphi-trace-export-serve-{}.json", std::process::id()));
    let cfg = ServeConfig {
        executors: 4,
        dispatch: DispatchMode::Decentralized,
        clients: 2,
        requests: 20,
        mix: vec![(ModelKind::Mlp, 1.0), (ModelKind::PathNet, 1.0)],
        op_spin_us: 200.0,
        trace_path: Some(path.to_string_lossy().into_owned()),
        ..ServeConfig::default()
    };
    let report = serve(&cfg);
    assert_eq!(report.completed, 20);
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let stats = validate_chrome_trace(&text).unwrap();
    assert_eq!(stats.processes, 1 + 20, "the fleet plus one process per session");
    assert!(stats.spans > 0);
    assert!(stats.instant_names.contains("admitted"), "{:?}", stats.instant_names);
    assert!(stats.instant_names.contains("done"), "{:?}", stats.instant_names);
    // 2 clients on 4 executors with 200µs ops: idle executors must park
    // or steal at least once, and those fleet events reach the trace
    assert!(
        stats.instant_names.contains("park") || stats.instant_names.contains("steal"),
        "expected at least one fleet instant class: {:?}",
        stats.instant_names
    );
}

/// `process_name → {(node id, span name)}` for every `X` span in a trace.
fn span_sets(text: &str) -> BTreeMap<String, BTreeSet<(u64, String)>> {
    let doc = graphi::util::json::parse(text).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let mut names: BTreeMap<u64, String> = BTreeMap::new();
    for ev in events {
        if ev.get("ph").and_then(|p| p.as_str()) == Some("M")
            && ev.get("name").and_then(|n| n.as_str()) == Some("process_name")
        {
            let pid = ev.get("pid").unwrap().as_f64().unwrap() as u64;
            let name =
                ev.get("args").unwrap().get("name").unwrap().as_str().unwrap().to_string();
            names.insert(pid, name);
        }
    }
    let mut sets: BTreeMap<String, BTreeSet<(u64, String)>> = BTreeMap::new();
    for ev in events {
        if ev.get("ph").and_then(|p| p.as_str()) == Some("X") {
            let pid = ev.get("pid").unwrap().as_f64().unwrap() as u64;
            let node = ev.get("args").unwrap().get("node").unwrap().as_f64().unwrap() as u64;
            let name = ev.get("name").unwrap().as_str().unwrap().to_string();
            sets.entry(names[&pid].clone()).or_default().insert((node, name));
        }
    }
    sets
}

#[test]
fn simulator_and_threaded_runtime_export_identical_op_span_sets() {
    let g1 = models::build_inference(ModelKind::Mlp, ModelSize::Small);
    let g2 = models::build_inference(ModelKind::PathNet, ModelSize::Small);
    let labels = ["session 1 (mlp)", "session 2 (pathnet)"];

    // simulator: both graphs concurrently on one virtual 2-executor fleet
    let env = SimEnv::knl(42);
    let (_, sessions) = GraphiEngine::new(2, 8).run_concurrent(&[&g1, &g2], &env);
    let sim_exports: Vec<SessionTraceExport<'_>> = sessions
        .iter()
        .zip([&g1, &g2])
        .zip(labels)
        .map(|((s, g), label)| SessionTraceExport {
            label: label.to_string(),
            graph: g,
            levels: None,
            records: &s.records,
            start_us: 0.0,
            end_us: s.makespan_us,
            outcome: "done".to_string(),
        })
        .collect();
    let sim_text = export_chrome_trace(&sim_exports, &[], 2);
    validate_chrome_trace(&sim_text).unwrap();

    // threaded runtime: the same graphs as real fleet sessions
    let cost = CostModel::knl();
    let mk_levels = |g: &Graph| -> Arc<[f64]> {
        let d: Vec<f64> = g.nodes().iter().map(|n| cost.duration_us(&n.kind, 8)).collect();
        cp_levels(g, &d).into()
    };
    let (l1, l2) = (mk_levels(&g1), mk_levels(&g2));
    let work: &(dyn Fn(NodeId) + Send + Sync) = &|_| {};
    let (r1, r2, events) = std::thread::scope(|scope| {
        let fleet = Fleet::new(scope, FleetConfig::new(2).with_event_recording(true));
        let r1 = fleet.submit(&g1, Arc::clone(&l1), work).wait().unwrap();
        let r2 = fleet.submit(&g2, Arc::clone(&l2), work).wait().unwrap();
        let events = fleet.drain_events();
        fleet.shutdown().unwrap();
        (r1, r2, events)
    });
    let thr_exports: Vec<SessionTraceExport<'_>> = [(&r1, &g1), (&r2, &g2)]
        .into_iter()
        .zip(labels)
        .map(|((r, g), label)| SessionTraceExport {
            label: label.to_string(),
            graph: g,
            levels: None,
            records: &r.records,
            start_us: r.submitted_at_us,
            end_us: r.submitted_at_us + r.wall_us,
            outcome: "done".to_string(),
        })
        .collect();
    let thr_text = export_chrome_trace(&thr_exports, &events, 2);
    validate_chrome_trace(&thr_text).unwrap();

    // same writer, same graphs → identical op-span sets per session
    let sim_spans = span_sets(&sim_text);
    let thr_spans = span_sets(&thr_text);
    assert_eq!(sim_spans, thr_spans);
    assert_eq!(sim_spans["session 1 (mlp)"].len(), g1.len());
    assert_eq!(sim_spans["session 2 (pathnet)"].len(), g2.len());
}
