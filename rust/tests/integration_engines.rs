//! Cross-module integration: models × engines × the paper's headline
//! claims, asserted as loose shapes (EXPERIMENTS.md records exact values).

use graphi::coordinator::config::{EngineChoice, ExperimentConfig};
use graphi::coordinator::driver::Driver;
use graphi::engine::{
    Engine, GraphiEngine, NaiveEngine, SequentialEngine, SimEnv, TensorFlowLikeEngine, Trace,
};
use graphi::models::{self, ModelKind, ModelSize};

#[test]
fn all_models_schedule_validly_under_all_engines() {
    let env = SimEnv::knl(5);
    for kind in [ModelKind::Lstm, ModelKind::PhasedLstm, ModelKind::PathNet, ModelKind::GoogleNet] {
        let g = models::build(kind, ModelSize::Small);
        for engine in [
            Box::new(GraphiEngine::new(8, 8)) as Box<dyn Engine>,
            Box::new(NaiveEngine::new(8, 8)),
            Box::new(SequentialEngine::new(64)),
            Box::new(TensorFlowLikeEngine::new(4, 16)),
        ] {
            let r = engine.run(&g, &env);
            r.validate(&g)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", kind.name(), engine.name()));
        }
    }
}

#[test]
fn headline_parallel_beats_sequential_on_every_model() {
    // §7.3 / Fig 6: parallel execution consistently outperforms sequential.
    let env = SimEnv::knl(6);
    for kind in [ModelKind::Lstm, ModelKind::PhasedLstm, ModelKind::PathNet, ModelKind::GoogleNet] {
        let g = models::build(kind, ModelSize::Small);
        let seq = SequentialEngine::new(64).run(&g, &env).makespan_us;
        // give each model a reasonable fleet (GoogleNet is narrow)
        let fleet: &[(usize, usize)] = &[(2, 32), (4, 16), (8, 8)];
        let best = fleet
            .iter()
            .map(|&(e, t)| GraphiEngine::new(e, t).run(&g, &env).makespan_us)
            .fold(f64::INFINITY, f64::min);
        assert!(
            best < seq,
            "{}: best parallel {best} ≥ sequential {seq}",
            kind.name()
        );
    }
}

#[test]
fn headline_speedup_band_vs_tensorflow() {
    // Fig 5 band: 2.1–9.5×. Assert a loose envelope on the small grid.
    let env = SimEnv::knl(7);
    for kind in [ModelKind::Lstm, ModelKind::PathNet, ModelKind::GoogleNet] {
        let g = models::build(kind, ModelSize::Small);
        let tf = [(2usize, 32usize), (4, 16), (8, 8)]
            .iter()
            .map(|&(i, t)| TensorFlowLikeEngine::new(i, t).run(&g, &env).makespan_us)
            .fold(f64::INFINITY, f64::min);
        let graphi = [(2usize, 32usize), (4, 16), (6, 10), (8, 8)]
            .iter()
            .map(|&(e, t)| GraphiEngine::new(e, t).run(&g, &env).makespan_us)
            .fold(f64::INFINITY, f64::min);
        let speedup = tf / graphi;
        assert!(
            (1.5..=15.0).contains(&speedup),
            "{}: speedup {speedup:.2} outside loose band",
            kind.name()
        );
    }
}

#[test]
fn fig6_optimum_tracks_graph_width() {
    let env = SimEnv::knl(8);
    // GoogleNet (2-4 parallel branches) must peak at few executors
    let goog = models::build(ModelKind::GoogleNet, ModelSize::Small);
    let configs = [(2usize, 32usize), (4, 16), (8, 8), (16, 4), (32, 2)];
    let times: Vec<f64> = configs
        .iter()
        .map(|&(e, t)| GraphiEngine::new(e, t).run(&goog, &env).makespan_us)
        .collect();
    let best_idx = times
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .unwrap()
        .0;
    assert!(best_idx <= 1, "GoogleNet optimum at {:?}", configs[best_idx]);
    // performance must degrade monotonically past the optimum
    assert!(times[4] > times[best_idx], "no decay past the optimum");
}

#[test]
fn table2_gap_largest_for_small_op_models() {
    // §7.4: LSTM-family gains exceed GoogleNet's because their ops are
    // smaller (heavier queue contention).
    let env = SimEnv::knl(9);
    let rel = |kind: ModelKind| {
        let g = models::build(kind, ModelSize::Small);
        let n = NaiveEngine::new(16, 4).run(&g, &env).makespan_us;
        let gr = GraphiEngine::new(16, 4).run(&g, &env).makespan_us;
        gr / n
    };
    let lstm = rel(ModelKind::Lstm);
    let goog = rel(ModelKind::GoogleNet);
    assert!(
        lstm < goog,
        "LSTM relative {lstm:.3} should beat GoogleNet's {goog:.3}"
    );
}

#[test]
fn wavefront_recovered_on_lstm() {
    // §7.4: CP-first recovers the cuDNN diagonal pattern.
    let g = models::build(ModelKind::Lstm, ModelSize::Small);
    let env = SimEnv::knl(10);
    let r = GraphiEngine::new(8, 8).run(&g, &env);
    let trace = Trace { records: r.records.clone() };
    let corr = trace.depth_time_correlation(&g);
    assert!(corr > 0.8, "depth/time correlation {corr:.3} too weak for a wavefront");
}

#[test]
fn driver_roundtrip_all_models() {
    for kind in [ModelKind::Lstm, ModelKind::PathNet] {
        let cfg = ExperimentConfig {
            model: kind,
            size: ModelSize::Small,
            engine: EngineChoice::Graphi,
            executors: Some(4),
            threads_per: Some(8),
            iterations: 2,
            ..Default::default()
        };
        let r = Driver::run(&cfg);
        assert!(r.mean_makespan_us > 0.0);
        assert!(r.std_us >= 0.0);
        assert_eq!(r.iterations, 2);
    }
}

#[test]
fn profiler_never_picks_single_executor_for_wide_models() {
    use graphi::engine::Profiler;
    let g = models::build(ModelKind::PathNet, ModelSize::Small);
    let p = Profiler { iterations: 1, worker_cores: 64, extra_configs: vec![(6, 10)] };
    let report = p.profile(&g, &SimEnv::knl(11));
    assert!(report.best.0 >= 2, "PathNet best fleet {:?}", report.best);
}

#[test]
fn skylake_machine_also_works() {
    // §9: Graphi generalizes to Xeon Platinum 8180 (28 cores).
    use graphi::cost::{Calibration, CostModel, Machine};
    let env = SimEnv {
        cost: CostModel { machine: Machine::skylake8180(), cal: Calibration::deterministic() },
        seed: 0,
    };
    let g = models::build(ModelKind::Lstm, ModelSize::Small);
    let seq = SequentialEngine::new(26).run(&g, &env).makespan_us;
    let par = GraphiEngine::new(4, 6).run(&g, &env).makespan_us;
    assert!(par < seq, "parallel {par} must beat sequential {seq} on SKX too");
}

#[test]
fn inference_graphs_are_forward_only() {
    use graphi::engine::dynamic::is_backward_op;
    for kind in [ModelKind::Lstm, ModelKind::PathNet, ModelKind::GoogleNet] {
        let train = models::build(kind, ModelSize::Small);
        let infer = models::build_inference(kind, ModelSize::Small);
        assert!(
            infer.len() * 2 < train.len() * 1 + train.len(),
            "{}: inference {} vs training {}",
            kind.name(),
            infer.len(),
            train.len()
        );
        assert!(infer.len() < train.len() / 2 + 10);
        assert!(
            !infer.nodes().iter().any(|n| is_backward_op(&n.name)),
            "{}: inference graph contains backward ops",
            kind.name()
        );
        // still a valid executable graph
        let r = GraphiEngine::new(4, 16).run(&infer, &SimEnv::knl(3));
        r.validate(&infer).unwrap();
    }
}

#[test]
fn dynamic_fleet_loses_to_static_on_every_model() {
    use graphi::engine::DynamicFleetEngine;
    let env = SimEnv::knl_deterministic();
    for kind in [ModelKind::Lstm, ModelKind::PathNet] {
        let g = models::build(kind, ModelSize::Small);
        let stat = GraphiEngine::new(8, 8).run(&g, &env).makespan_us;
        let dynamic = DynamicFleetEngine::new((8, 8), (16, 4)).run(&g, &env).makespan_us;
        assert!(dynamic > stat, "{}: dynamic {dynamic} vs static {stat}", kind.name());
    }
}

#[test]
fn locality_mode_valid_and_competitive() {
    let g = models::build(ModelKind::Lstm, ModelSize::Small);
    let env = SimEnv::knl_deterministic();
    let base = GraphiEngine::new(8, 8).run(&g, &env);
    let local = GraphiEngine { locality: true, ..GraphiEngine::new(8, 8) }.run(&g, &env);
    local.validate(&g).unwrap();
    // §6: "modest margin" either way — must not blow up
    let rel = local.makespan_us / base.makespan_us;
    assert!((0.85..=1.10).contains(&rel), "locality rel {rel}");
}

#[test]
fn straggler_degrades_gracefully() {
    let g = models::build(ModelKind::Lstm, ModelSize::Small);
    let env = SimEnv::knl_deterministic();
    let base = GraphiEngine::new(8, 8).run(&g, &env).makespan_us;
    let slow = GraphiEngine { straggler: Some((0, 3.0)), ..GraphiEngine::new(8, 8) }
        .run(&g, &env);
    slow.validate(&g).unwrap();
    let rel = slow.makespan_us / base;
    // one of eight executors at 3×: bounded well below a global 3× slowdown
    assert!(rel > 1.0 && rel < 3.0, "straggler rel {rel}");
}

#[test]
fn memory_plan_of_engine_schedule_is_valid() {
    use graphi::graph::plan_memory;
    let g = models::build(ModelKind::PathNet, ModelSize::Small);
    let r = GraphiEngine::new(4, 16).run(&g, &SimEnv::knl_deterministic());
    // execution order by start time is a valid topological order
    let mut order: Vec<_> = r.records.clone();
    order.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
    let order: Vec<_> = order.into_iter().map(|rec| rec.node).collect();
    let plan = plan_memory(&g, &order);
    plan.validate().unwrap();
    assert!(plan.fits(16 << 30));
}

#[test]
fn snc4_mode_runs_and_stays_close_to_quadrant() {
    // §9 future work: SNC-4 under contiguous packing is ≈neutral — local
    // boosts and span penalties nearly cancel.
    use graphi::cost::{Calibration, CostModel, Machine};
    let g = models::build(ModelKind::Lstm, ModelSize::Small);
    let run = |machine: Machine| {
        let env = SimEnv { cost: CostModel { machine, cal: Calibration::deterministic() }, seed: 0 };
        GraphiEngine::new(4, 16).run(&g, &env).makespan_us
    };
    let quadrant = run(Machine::knl7250());
    let snc = run(Machine::knl7250_snc4());
    let rel = snc / quadrant;
    assert!((0.9..=1.15).contains(&rel), "snc4/quadrant = {rel}");
}
