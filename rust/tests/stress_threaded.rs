//! Seeded concurrency stress harness for the threaded runtime.
//!
//! Wide fan-in/fan-out DAGs — diamond chains, a butterfly (FFT-style
//! crossing fan-in), and 1→N→1 fans — run many times on 2/4/8 executors
//! in both dispatch architectures, asserting the three invariants every
//! run of the decentralized machinery must uphold:
//!
//! 1. **exactly-once**: every op's work closure fires once (no double
//!    trigger from the `fetch_sub` resolution, no lost entry in a deque);
//! 2. **dependency order**: an atomic-clock stamp taken inside the work
//!    closure is strictly increasing along every edge;
//! 3. **clean quiescence**: the run *returns* — the executor fleet parks
//!    and exits instead of hanging on a lost wakeup or a missed done
//!    flag. Each run is wrapped in a watchdog (detached worker + channel
//!    `recv_timeout`), so a hang fails the test in bounded time instead
//!    of stalling CI; the workflow additionally runs this suite under a
//!    job-level hard timeout in release mode.
//!
//! Seeds (`GRAPHI_TEST_SEED` to override) vary the level values per
//! iteration so dispatch order, steal targets and park/wake interleavings
//! differ run to run.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use graphi::engine::{DispatchMode, DomainMap, PhasePlan};
use graphi::graph::op::OpKind;
use graphi::graph::{Graph, GraphBuilder, NodeId};
use graphi::runtime::{Fleet, FleetConfig, ThreadedGraphi};
use graphi::util::rng::Rng;

const ITERATIONS: usize = 100;
const FLEETS: [usize; 3] = [2, 4, 8];
/// Generous per-run watchdog: a healthy run of these ≤130-node graphs
/// finishes in milliseconds even on a loaded 1-core host.
const WATCHDOG: Duration = Duration::from_secs(60);

fn base_seed() -> u64 {
    std::env::var("GRAPHI_TEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x57E55)
}

/// A chain of diamonds: a → {b,c} → d, repeated `links` times in series.
/// Fan-out then immediate fan-in, the classic double-trigger shape.
fn diamond_chain(links: usize) -> Graph {
    let mut b = GraphBuilder::new();
    let mut join = b.add("src", OpKind::Scalar);
    for l in 0..links {
        let left = b.add(format!("l{l}"), OpKind::Scalar);
        let right = b.add(format!("r{l}"), OpKind::Scalar);
        b.depend(join, left);
        b.depend(join, right);
        join = b.add_after(format!("j{l}"), OpKind::Scalar, &[left, right]);
    }
    b.build().unwrap()
}

/// An FFT-style butterfly: `layers` layers of `width` nodes; node (l+1, i)
/// depends on (l, i) and its crossing partner (l, i ^ stride). Every op
/// except the first layer is a 2-fan-in, every op except the last feeds 2.
fn butterfly(layers: usize, width: usize) -> Graph {
    assert!(width.is_power_of_two());
    let mut b = GraphBuilder::new();
    let mut prev: Vec<NodeId> =
        (0..width).map(|i| b.add(format!("b0_{i}"), OpKind::Scalar)).collect();
    for l in 1..layers {
        let stride = 1 << ((l - 1) % width.trailing_zeros().max(1) as usize);
        let this: Vec<NodeId> = (0..width)
            .map(|i| {
                b.add_after(
                    format!("b{l}_{i}"),
                    OpKind::Scalar,
                    &[prev[i], prev[i ^ (stride % width)]],
                )
            })
            .collect();
        prev = this;
    }
    b.build().unwrap()
}

/// 1 → N → 1: one source fanning out to `n` parallel ops, all fanning
/// back into one sink — maximum simultaneous ready width, then an
/// n-way fan-in on the final counter.
fn fan(n: usize) -> Graph {
    let mut b = GraphBuilder::new();
    let src = b.add("src", OpKind::Scalar);
    let mids: Vec<NodeId> = (0..n)
        .map(|i| {
            let m = b.add(format!("m{i}"), OpKind::Scalar);
            b.depend(src, m);
            m
        })
        .collect();
    b.add_after("sink", OpKind::Scalar, &mids);
    b.build().unwrap()
}

/// What one stressed run reports back through the watchdog channel.
struct RunOutcome {
    records: usize,
    dispatches: u64,
    mode_switches: u64,
    counts: Vec<u32>,
    stamps: Vec<u64>,
}

/// Execute one run on a detached worker thread and wait for it under the
/// watchdog. A hang (lost wakeup, missed quiescence flag) trips the
/// timeout instead of stalling the suite — the worker thread is
/// deliberately *not* joined in that case; the panic fails the test and
/// process teardown reaps it.
fn run_with_watchdog(graph: &Arc<Graph>, engine: ThreadedGraphi, levels: Vec<f64>, tag: &str) -> RunOutcome {
    let (tx, rx) = mpsc::channel();
    let g = Arc::clone(graph);
    std::thread::spawn(move || {
        let n = g.len();
        let counts: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let clock = AtomicU64::new(1);
        let stamps: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        let result = engine
            .run(&g, levels, |v| {
                counts[v as usize].fetch_add(1, Ordering::SeqCst);
                let t = clock.fetch_add(1, Ordering::SeqCst);
                stamps[v as usize].store(t, Ordering::SeqCst);
            })
            .expect("cp-first runs are always supported");
        let _ = tx.send(RunOutcome {
            records: result.records.len(),
            dispatches: result.dispatches,
            mode_switches: result.mode_switches,
            counts: counts.iter().map(|c| c.load(Ordering::SeqCst)).collect(),
            stamps: stamps.iter().map(|s| s.load(Ordering::SeqCst)).collect(),
        });
    });
    match rx.recv_timeout(WATCHDOG) {
        Ok(outcome) => outcome,
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("{tag}: no quiescence within {WATCHDOG:?} — dispatch hang")
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => {
            panic!("{tag}: worker thread panicked inside the run")
        }
    }
}

/// The three invariants, checked against the graph.
fn assert_invariants(graph: &Graph, outcome: &RunOutcome, tag: &str) {
    assert_eq!(outcome.records, graph.len(), "{tag}: record count");
    assert_eq!(outcome.dispatches, graph.len() as u64, "{tag}: dispatch count");
    for (v, &c) in outcome.counts.iter().enumerate() {
        assert_eq!(c, 1, "{tag}: node {v} executed {c} times");
    }
    for v in 0..graph.len() as NodeId {
        let tv = outcome.stamps[v as usize];
        assert!(tv > 0, "{tag}: node {v} never stamped");
        for &p in graph.preds(v) {
            let tp = outcome.stamps[p as usize];
            assert!(tp < tv, "{tag}: dep violated {p}(t={tp}) vs {v}(t={tv})");
        }
    }
}

/// Per-iteration level values: seeded random priorities so the CP order,
/// deque contents and steal targets differ every run.
fn seeded_levels(n: usize, rng: &mut Rng) -> Vec<f64> {
    (0..n).map(|_| rng.uniform(0.5, 1000.0)).collect()
}

fn stress(graph: Graph, name: &str) {
    let graph = Arc::new(graph);
    let mut rng = Rng::new(base_seed() ^ name.len() as u64);
    for iter in 0..ITERATIONS {
        for &execs in &FLEETS {
            for mode in DispatchMode::ALL {
                let tag = format!("{name}/iter{iter}/{execs}exec/{}", mode.name());
                let engine = ThreadedGraphi::new(execs).with_dispatch(mode);
                let levels = seeded_levels(graph.len(), &mut rng);
                let outcome = run_with_watchdog(&graph, engine, levels, &tag);
                assert_invariants(&graph, &outcome, &tag);
            }
        }
    }
}

#[test]
fn stress_diamond_chain_both_modes_all_fleets() {
    stress(diamond_chain(16), "diamond");
}

#[test]
fn stress_butterfly_both_modes_all_fleets() {
    stress(butterfly(8, 8), "butterfly");
}

#[test]
fn stress_fan_out_fan_in_both_modes_all_fleets() {
    stress(fan(32), "fan");
}

/// Per-session outcome of one multi-session fleet run.
struct SessionOutcome {
    records: usize,
    dispatches: u64,
    counts: Vec<u32>,
    stamps: Vec<u64>,
}

/// Concurrent sessions on ONE shared persistent fleet: ≥4 graphs in
/// flight at once, both dispatch modes, 2/4/8 executors, seeded levels,
/// per-run watchdog. Asserts per-session exactly-once, per-session
/// dependency order, the per-session/fleet metric partition, and a clean
/// fleet shutdown (threads spawned once and all joined — `shutdown()`
/// returning IS the no-leaked-parked-threads proof, since it joins every
/// handle under the same watchdog).
#[test]
fn stress_concurrent_sessions_shared_fleet() {
    let graphs: Vec<Arc<Graph>> = vec![
        Arc::new(diamond_chain(12)),
        Arc::new(butterfly(6, 8)),
        Arc::new(fan(24)),
        Arc::new(diamond_chain(4)),
    ];
    let mut rng = Rng::new(base_seed() ^ 0x5E55);
    for iter in 0..25 {
        for &execs in &FLEETS {
            for mode in DispatchMode::ALL {
                let tag = format!("sessions/iter{iter}/{execs}exec/{}", mode.name());
                let level_sets: Vec<Vec<f64>> =
                    graphs.iter().map(|g| seeded_levels(g.len(), &mut rng)).collect();
                let (tx, rx) = mpsc::channel();
                let worker_graphs = graphs.clone();
                std::thread::spawn(move || {
                    let graphs = worker_graphs;
                    // per-session instrumentation, Arc'd so the boxed work
                    // closures are 'static and still readable afterwards
                    type SessionProbe = (Vec<AtomicU32>, AtomicU64, Vec<AtomicU64>);
                    let per_graph: Vec<Arc<SessionProbe>> = graphs
                        .iter()
                        .map(|g| {
                            Arc::new((
                                (0..g.len()).map(|_| AtomicU32::new(0)).collect(),
                                AtomicU64::new(1),
                                (0..g.len()).map(|_| AtomicU64::new(0)).collect(),
                            ))
                        })
                        .collect();
                    let works: Vec<Box<dyn Fn(NodeId) + Send + Sync>> = per_graph
                        .iter()
                        .map(|probe| {
                            let probe = Arc::clone(probe);
                            Box::new(move |v: NodeId| {
                                probe.0[v as usize].fetch_add(1, Ordering::SeqCst);
                                let t = probe.1.fetch_add(1, Ordering::SeqCst);
                                probe.2[v as usize].store(t, Ordering::SeqCst);
                            }) as Box<dyn Fn(NodeId) + Send + Sync>
                        })
                        .collect();
                    let (outcomes, totals) = std::thread::scope(|scope| {
                        let fleet = Fleet::new(
                            scope,
                            FleetConfig::new(execs).with_dispatch(mode),
                        );
                        // all sessions submitted before any wait ⇒ they
                        // are in flight concurrently on the one fleet
                        let handles: Vec<_> = graphs
                            .iter()
                            .zip(&level_sets)
                            .zip(&works)
                            .map(|((g, levels), work)| {
                                fleet.submit(g, levels.clone(), work.as_ref())
                            })
                            .collect();
                        let reports: Vec<_> = handles
                            .into_iter()
                            .map(|h| h.wait().expect("healthy session"))
                            .collect();
                        (reports, fleet.shutdown().expect("clean fleet"))
                    });
                    let sessions: Vec<SessionOutcome> = outcomes
                        .iter()
                        .zip(&per_graph)
                        .map(|(r, probe)| SessionOutcome {
                            records: r.records.len(),
                            dispatches: r.dispatches,
                            counts: probe.0.iter().map(|c| c.load(Ordering::SeqCst)).collect(),
                            stamps: probe.2.iter().map(|s| s.load(Ordering::SeqCst)).collect(),
                        })
                        .collect();
                    let session_steals: u64 = outcomes.iter().map(|r| r.steals).sum();
                    let session_dispatches: u64 = outcomes.iter().map(|r| r.dispatches).sum();
                    let _ = tx.send((sessions, session_steals, session_dispatches, totals));
                });
                let (sessions, session_steals, session_dispatches, totals) =
                    match rx.recv_timeout(WATCHDOG) {
                        Ok(out) => out,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            panic!("{tag}: no quiescence within {WATCHDOG:?} — dispatch hang")
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            panic!("{tag}: worker thread panicked inside the run")
                        }
                    };
                // threads spawned once, never per session (post-join
                // snapshot: every started thread is counted)
                assert_eq!(totals.executor_threads, execs as u64, "{tag}: executor thread count");
                for (si, (graph, s)) in graphs.iter().zip(&sessions).enumerate() {
                    let stag = format!("{tag}/s{si}");
                    assert_eq!(s.records, graph.len(), "{stag}: record count");
                    assert_eq!(s.dispatches, graph.len() as u64, "{stag}: dispatches");
                    for (v, &c) in s.counts.iter().enumerate() {
                        assert_eq!(c, 1, "{stag}: node {v} executed {c} times");
                    }
                    for v in 0..graph.len() as NodeId {
                        let tv = s.stamps[v as usize];
                        assert!(tv > 0, "{stag}: node {v} never stamped");
                        for &p in graph.preds(v) {
                            let tp = s.stamps[p as usize];
                            assert!(tp < tv, "{stag}: dep violated {p}(t={tp}) vs {v}(t={tv})");
                        }
                    }
                }
                // metric partition: per-session sums vs fleet totals
                assert_eq!(
                    session_dispatches, totals.dispatches,
                    "{tag}: every dispatch belongs to exactly one session"
                );
                assert!(
                    session_steals <= totals.steals,
                    "{tag}: session steals {session_steals} exceed fleet total {}",
                    totals.steals
                );
                assert_eq!(totals.sessions_completed, graphs.len() as u64, "{tag}");
            }
        }
    }
}

/// PR 6 chaos: the same 4-graph concurrent mix, but every iteration
/// injects seeded faults — op panics, client cancels, and op delays
/// under a deadline tighter than the delay — across both dispatch modes
/// and 2/4/8 executors, [`ITERATIONS`] iterations per config. Asserts:
///
/// * **confinement**: healthy sessions keep exactly-once + dep order;
/// * **no zombie ops**: terminated sessions never run an op twice, and
///   whatever prefix they did run is dependency-closed;
/// * **structured outcomes**: every terminal matches its injected fault
///   (a panic plan can never end `Ok`, a cancel can never be blamed on a
///   deadline, …), and outcome counts conserve across the fleet totals;
/// * **no leaks**: admission budget returns to zero (RAII permits across
///   panics), executor threads are joined (thread count exact, no
///   executor killed by an op panic), and the channel watchdog bounds
///   every run — a hang is a failure, not a stall.
#[test]
fn stress_fault_injection_shared_fleet() {
    use graphi::runtime::{SessionError, SessionQueue};
    use graphi::util::testkit::FaultPlan;

    let graphs: Vec<Arc<Graph>> = vec![
        Arc::new(diamond_chain(12)),
        Arc::new(butterfly(6, 8)),
        Arc::new(fan(24)),
        Arc::new(diamond_chain(4)),
    ];
    let mut rng = Rng::new(base_seed() ^ 0xFA17);
    for iter in 0..ITERATIONS {
        for &execs in &FLEETS {
            for mode in DispatchMode::ALL {
                let tag = format!("faults/iter{iter}/{execs}exec/{}", mode.name());
                let level_sets: Vec<Vec<f64>> =
                    graphs.iter().map(|g| seeded_levels(g.len(), &mut rng)).collect();
                let plans: Vec<FaultPlan> = graphs
                    .iter()
                    .map(|g| FaultPlan::draw(&mut rng, g.len(), 0.7, 200.0))
                    .collect();
                let (tx, rx) = mpsc::channel();
                let worker_graphs = graphs.clone();
                let worker_plans = plans.clone();
                std::thread::spawn(move || {
                    let graphs = worker_graphs;
                    let plans = worker_plans;
                    type SessionProbe = (Vec<AtomicU32>, AtomicU64, Vec<AtomicU64>);
                    let per_graph: Vec<Arc<SessionProbe>> = graphs
                        .iter()
                        .map(|g| {
                            Arc::new((
                                (0..g.len()).map(|_| AtomicU32::new(0)).collect(),
                                AtomicU64::new(1),
                                (0..g.len()).map(|_| AtomicU64::new(0)).collect(),
                            ))
                        })
                        .collect();
                    let works: Vec<Box<dyn Fn(NodeId) + Send + Sync>> = per_graph
                        .iter()
                        .zip(&plans)
                        .map(|(probe, plan)| {
                            let probe = Arc::clone(probe);
                            Box::new(plan.clone().wrap(move |v: NodeId| {
                                probe.0[v as usize].fetch_add(1, Ordering::SeqCst);
                                let t = probe.1.fetch_add(1, Ordering::SeqCst);
                                probe.2[v as usize].store(t, Ordering::SeqCst);
                            })) as Box<dyn Fn(NodeId) + Send + Sync>
                        })
                        .collect();
                    // one admission unit per session: permits must all come
                    // back even when their session panics
                    let queue = SessionQueue::new(graphs.len() as u64);
                    let (outcomes, shutdown) = std::thread::scope(|scope| {
                        let fleet = Fleet::new(
                            scope,
                            FleetConfig::new(execs)
                                .with_dispatch(mode)
                                .with_watchdog(Duration::from_secs(10)),
                        );
                        let permits: Vec<_> = graphs.iter().map(|_| queue.admit(1)).collect();
                        let handles: Vec<_> = graphs
                            .iter()
                            .zip(&level_sets)
                            .zip(&works)
                            .zip(&plans)
                            .map(|(((g, levels), work), plan)| {
                                // delay-fault sessions carry a deadline
                                // tighter than their injected delay
                                if plan.delay_at.is_some() {
                                    fleet.submit_with_deadline(
                                        g,
                                        levels.clone(),
                                        work.as_ref(),
                                        Duration::from_micros(100),
                                    )
                                } else {
                                    fleet.submit(g, levels.clone(), work.as_ref())
                                }
                            })
                            .collect();
                        // client-side cancels after the drawn delay
                        if plans.iter().any(|p| p.cancel_after_us.is_some()) {
                            std::thread::sleep(Duration::from_micros(200));
                            for (h, plan) in handles.iter().zip(&plans) {
                                if plan.cancel_after_us.is_some() {
                                    h.cancel();
                                }
                            }
                        }
                        let outcomes: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
                        drop(permits);
                        assert_eq!(queue.in_use(), 0, "leaked admission budget");
                        assert_eq!(queue.waiting(), 0, "phantom admission waiters");
                        (outcomes, fleet.shutdown())
                    });
                    let counts: Vec<Vec<u32>> = per_graph
                        .iter()
                        .map(|p| p.0.iter().map(|c| c.load(Ordering::SeqCst)).collect())
                        .collect();
                    let stamps: Vec<Vec<u64>> = per_graph
                        .iter()
                        .map(|p| p.2.iter().map(|s| s.load(Ordering::SeqCst)).collect())
                        .collect();
                    let _ = tx.send((outcomes, counts, stamps, shutdown));
                });
                let (outcomes, counts, stamps, shutdown) = match rx.recv_timeout(WATCHDOG) {
                    Ok(out) => out,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        panic!("{tag}: no quiescence within {WATCHDOG:?} — dispatch hang")
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        panic!("{tag}: worker thread panicked inside the run")
                    }
                };
                let mut expected_failed = 0u64;
                for (si, ((graph, plan), outcome)) in
                    graphs.iter().zip(&plans).zip(&outcomes).enumerate()
                {
                    let stag = format!("{tag}/s{si}");
                    let c = &counts[si];
                    let st = &stamps[si];
                    // never-twice, and the executed set is a
                    // dependency-closed prefix regardless of outcome
                    for (v, &n) in c.iter().enumerate() {
                        assert!(n <= 1, "{stag}: node {v} executed {n} times");
                        if n == 1 {
                            for &p in graph.preds(v as NodeId) {
                                assert_eq!(
                                    c[p as usize], 1,
                                    "{stag}: node {v} ran but its dep {p} never did"
                                );
                                assert!(
                                    st[p as usize] < st[v],
                                    "{stag}: dep violated {p} vs {v}"
                                );
                            }
                        }
                    }
                    match outcome {
                        Ok(r) => {
                            assert!(
                                plan.panic_at.is_none(),
                                "{stag}: panic plan completed: {plan:?}"
                            );
                            assert_eq!(r.records.len(), graph.len(), "{stag}: record count");
                            assert!(
                                c.iter().all(|&n| n == 1),
                                "{stag}: Ok session with missing ops"
                            );
                        }
                        Err(SessionError::OpPanicked { node, payload }) => {
                            expected_failed += 1;
                            assert_eq!(Some(*node), plan.panic_at, "{stag}: wrong blamed node");
                            assert!(
                                payload.contains(FaultPlan::PANIC_TAG),
                                "{stag}: foreign panic payload: {payload}"
                            );
                            assert_eq!(
                                c[*node as usize], 0,
                                "{stag}: panicked op counted as executed"
                            );
                        }
                        Err(SessionError::Cancelled) => {
                            assert!(plan.cancel_after_us.is_some(), "{stag}: spurious cancel");
                        }
                        Err(SessionError::DeadlineExceeded) => {
                            assert!(plan.delay_at.is_some(), "{stag}: spurious deadline miss");
                        }
                        Err(other) => panic!("{stag}: unexpected terminal {other:?}"),
                    }
                }
                let totals = match shutdown {
                    Ok(t) => {
                        assert_eq!(
                            expected_failed, 0,
                            "{tag}: sessions failed but shutdown reported clean"
                        );
                        t
                    }
                    Err(e) => {
                        assert!(
                            e.panicked_threads.is_empty(),
                            "{tag}: fleet thread died: {:?}",
                            e.panicked_threads
                        );
                        assert_eq!(e.sessions_failed, expected_failed, "{tag}: failure count");
                        e.totals
                    }
                };
                assert_eq!(
                    totals.executor_threads, execs as u64,
                    "{tag}: executor threads leaked or respawned"
                );
                assert_eq!(
                    totals.sessions_completed
                        + totals.sessions_failed
                        + totals.sessions_cancelled
                        + totals.sessions_deadline_missed,
                    graphs.len() as u64,
                    "{tag}: session outcomes must conserve"
                );
            }
        }
    }
}

/// PR 8 overload chaos: a seeded [`OverloadPlan`] — an arrival **burst**
/// at t = 0 plus trailing arrivals, every session under one tight
/// deadline (doubling as admission patience), one op panic and a
/// sprinkle of cancels — replayed against a shared fleet behind a
/// 3-unit admission budget, across both dispatch modes, 2/4 executors
/// and all three admission policies. Asserts, under the channel
/// watchdog:
///
/// * **exact 5-class conservation**: completed + failed + cancelled +
///   deadline_missed + shed equals the offered request count, and each
///   client-observed class matches the fleet's own totals counter;
/// * **structured outcomes**: a panic terminal only on the panic plan
///   (with the testkit payload tag), a cancel terminal only on a cancel
///   plan — and no session ever runs an op twice;
/// * **no leaks**: the admission budget returns to zero with no phantom
///   waiters (RAII permits across panics, sheds and misses), and the
///   executor thread count is exact after shutdown.
#[test]
fn stress_overload_shared_fleet() {
    use graphi::runtime::{AdmissionPolicy, AdmitRequest, SessionError, SessionQueue};
    use graphi::util::testkit::{FaultPlan, OverloadPlan};

    // overload runs sleep through real arrival gaps and deadlines, so
    // fewer, bigger iterations than the microsecond-scale suites
    const OVERLOAD_ITERS: usize = 20;
    const SESSIONS: usize = 12;
    const GAP_US: u64 = 2_000;
    const DEADLINE_US: u64 = 3_000;
    const OP_SLEEP_US: u64 = 100;
    const BUDGET: u64 = 3;

    let graph = Arc::new(diamond_chain(6));
    let mut rng = Rng::new(base_seed() ^ 0x0E21);
    for iter in 0..OVERLOAD_ITERS {
        let policy = AdmissionPolicy::ALL[iter % AdmissionPolicy::ALL.len()];
        for &execs in &FLEETS[..2] {
            for mode in DispatchMode::ALL {
                let tag =
                    format!("overload/iter{iter}/{execs}exec/{}/{}", mode.name(), policy.name());
                let plan = OverloadPlan::draw(&mut rng, SESSIONS, graph.len(), GAP_US, DEADLINE_US);
                let level_sets: Vec<Vec<f64>> =
                    (0..SESSIONS).map(|_| seeded_levels(graph.len(), &mut rng)).collect();
                let (tx, rx) = mpsc::channel();
                let worker_graph = Arc::clone(&graph);
                let worker_plan = plan.clone();
                std::thread::spawn(move || {
                    let graph = worker_graph;
                    let plan = worker_plan;
                    let deadline = Duration::from_micros(DEADLINE_US);
                    let probes: Vec<Arc<Vec<AtomicU32>>> = (0..SESSIONS)
                        .map(|_| Arc::new((0..graph.len()).map(|_| AtomicU32::new(0)).collect()))
                        .collect();
                    let works: Vec<Box<dyn Fn(NodeId) + Send + Sync>> = plan
                        .plans
                        .iter()
                        .zip(&probes)
                        .map(|(p, probe)| {
                            let probe = Arc::clone(probe);
                            Box::new(p.clone().wrap(move |v: NodeId| {
                                probe[v as usize].fetch_add(1, Ordering::SeqCst);
                                std::thread::sleep(Duration::from_micros(OP_SLEEP_US));
                            })) as Box<dyn Fn(NodeId) + Send + Sync>
                        })
                        .collect();
                    let queue = SessionQueue::new(BUDGET).with_policy(policy);
                    // completed / failed / cancelled / deadline_missed / shed
                    let classes: [AtomicU64; 5] = std::array::from_fn(|_| AtomicU64::new(0));
                    let shutdown = std::thread::scope(|scope| {
                        let fleet = Fleet::new(
                            scope,
                            FleetConfig::new(execs)
                                .with_dispatch(mode)
                                .with_watchdog(Duration::from_secs(10)),
                        );
                        let fleet_ref = &fleet;
                        let queue_ref = &queue;
                        let classes = &classes;
                        let g: &Graph = &graph;
                        std::thread::scope(|clients| {
                            for i in 0..SESSIONS {
                                let arrive = plan.arrive_us[i];
                                let session_plan = plan.plans[i].clone();
                                let levels = level_sets[i].clone();
                                let work = works[i].as_ref();
                                clients.spawn(move || {
                                    std::thread::sleep(Duration::from_micros(arrive));
                                    let req = AdmitRequest::new(1)
                                        .with_class((i % 3) as u8)
                                        .with_patience(deadline);
                                    let permit = match queue_ref.admit_request(req) {
                                        Ok(p) => p,
                                        Err(_) => {
                                            fleet_ref.record_shed();
                                            classes[4].fetch_add(1, Ordering::SeqCst);
                                            return;
                                        }
                                    };
                                    let handle =
                                        fleet_ref.submit_with_deadline(g, levels, work, deadline);
                                    if let Some(after_us) = session_plan.cancel_after_us {
                                        std::thread::sleep(Duration::from_micros(after_us as u64));
                                        handle.cancel();
                                    }
                                    let class = match handle.wait() {
                                        Ok(_) => {
                                            assert!(
                                                session_plan.panic_at.is_none(),
                                                "s{i}: panic plan completed"
                                            );
                                            0
                                        }
                                        Err(SessionError::OpPanicked { node, payload }) => {
                                            assert_eq!(
                                                Some(node),
                                                session_plan.panic_at,
                                                "s{i}: wrong blamed node"
                                            );
                                            assert!(
                                                payload.contains(FaultPlan::PANIC_TAG),
                                                "s{i}: foreign panic payload: {payload}"
                                            );
                                            1
                                        }
                                        Err(SessionError::Cancelled) => {
                                            assert!(
                                                session_plan.cancel_after_us.is_some(),
                                                "s{i}: spurious cancel"
                                            );
                                            2
                                        }
                                        Err(SessionError::DeadlineExceeded) => 3,
                                        Err(other) => panic!("s{i}: unexpected terminal {other:?}"),
                                    };
                                    drop(permit);
                                    classes[class].fetch_add(1, Ordering::SeqCst);
                                });
                            }
                        });
                        assert_eq!(queue.in_use(), 0, "leaked admission budget");
                        assert_eq!(queue.waiting(), 0, "phantom admission waiters");
                        fleet.shutdown()
                    });
                    let classes: Vec<u64> =
                        classes.iter().map(|c| c.load(Ordering::SeqCst)).collect();
                    let probe_counts: Vec<Vec<u32>> = probes
                        .iter()
                        .map(|p| p.iter().map(|c| c.load(Ordering::SeqCst)).collect())
                        .collect();
                    let _ = tx.send((classes, probe_counts, shutdown));
                });
                let (classes, probe_counts, shutdown) = match rx.recv_timeout(WATCHDOG) {
                    Ok(out) => out,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        panic!("{tag}: no quiescence within {WATCHDOG:?} — overload hang")
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        panic!("{tag}: worker thread panicked inside the run")
                    }
                };
                for (si, counts) in probe_counts.iter().enumerate() {
                    for (v, &n) in counts.iter().enumerate() {
                        assert!(n <= 1, "{tag}/s{si}: node {v} executed {n} times");
                    }
                }
                let totals = match shutdown {
                    Ok(t) => {
                        assert_eq!(classes[1], 0, "{tag}: failures but shutdown reported clean");
                        t
                    }
                    Err(e) => {
                        assert!(
                            e.panicked_threads.is_empty(),
                            "{tag}: fleet thread died: {:?}",
                            e.panicked_threads
                        );
                        e.totals
                    }
                };
                assert_eq!(
                    totals.executor_threads, execs as u64,
                    "{tag}: executor threads leaked or respawned"
                );
                // the fleet ledger and the client-observed classes must be
                // the same story, class by class…
                assert_eq!(totals.sessions_completed, classes[0], "{tag}: completed");
                assert_eq!(totals.sessions_failed, classes[1], "{tag}: failed");
                assert_eq!(totals.sessions_cancelled, classes[2], "{tag}: cancelled");
                assert_eq!(totals.sessions_deadline_missed, classes[3], "{tag}: deadline_missed");
                assert_eq!(totals.sessions_shed, classes[4], "{tag}: shed");
                // …and the five classes must conserve the offered load
                assert_eq!(
                    classes.iter().sum::<u64>(),
                    SESSIONS as u64,
                    "{tag}: 5-class conservation: {classes:?}"
                );
            }
        }
    }
}

/// PR 10 moldable chaos: the same shared-fleet graph mix submitted as
/// **moldable sessions** — per-node gang widths drawn seeded in 1..=4,
/// so pops form gangs (a leader plus recruited peers) that shrink when
/// the fleet is busy — with seeded faults whose panics land on the
/// gang's **highest rank** ([`FaultPlan::wrap_wide`]), exercising the
/// member → `fail_session` confinement path. Asserts under the channel
/// watchdog, across both dispatch modes and 2/4/8 executors:
///
/// * **gang exactly-once**: rank 0 fires exactly once per node, and
///   every call observes `rank < width ≤ requested width`;
/// * **dependency order**: rank-0 stamps are increasing along every
///   edge of the executed (dependency-closed) prefix — a gang resolves
///   its successors only after every seated member returned;
/// * **confinement**: a member panic fails only its own session, blamed
///   on the right node with the testkit payload tag, while sibling
///   sessions stay healthy;
/// * **no leaks**: executor thread count exact after shutdown, and the
///   4-class session outcomes conserve.
#[test]
fn stress_moldable_gang_faults_shared_fleet() {
    use graphi::runtime::SessionError;
    use graphi::util::testkit::FaultPlan;

    let graphs: Vec<Arc<Graph>> = vec![
        Arc::new(diamond_chain(12)),
        Arc::new(fan(24)),
        Arc::new(butterfly(6, 8)),
    ];
    let mut rng = Rng::new(base_seed() ^ 0x6A96);
    for iter in 0..ITERATIONS {
        for &execs in &FLEETS {
            for mode in DispatchMode::ALL {
                let tag = format!("moldable/iter{iter}/{execs}exec/{}", mode.name());
                let level_sets: Vec<Vec<f64>> =
                    graphs.iter().map(|g| seeded_levels(g.len(), &mut rng)).collect();
                let width_sets: Vec<Vec<u8>> = graphs
                    .iter()
                    .map(|g| (0..g.len()).map(|_| rng.below(4) as u8 + 1).collect())
                    .collect();
                let plans: Vec<FaultPlan> = graphs
                    .iter()
                    .map(|g| FaultPlan::draw(&mut rng, g.len(), 0.4, 50.0))
                    .collect();
                let (tx, rx) = mpsc::channel();
                let worker_graphs = graphs.clone();
                let worker_plans = plans.clone();
                let worker_widths = width_sets.clone();
                std::thread::spawn(move || {
                    let graphs = worker_graphs;
                    let plans = worker_plans;
                    let width_sets = worker_widths;
                    // per session: (rank-0 counts, clock, rank-0 stamps,
                    // seat-contract violations)
                    type GangProbe = (Vec<AtomicU32>, AtomicU64, Vec<AtomicU64>, AtomicU32);
                    let per_graph: Vec<Arc<GangProbe>> = graphs
                        .iter()
                        .map(|g| {
                            Arc::new((
                                (0..g.len()).map(|_| AtomicU32::new(0)).collect(),
                                AtomicU64::new(1),
                                (0..g.len()).map(|_| AtomicU64::new(0)).collect(),
                                AtomicU32::new(0),
                            ))
                        })
                        .collect();
                    let works: Vec<Arc<dyn Fn(NodeId, u32, u32) + Send + Sync>> = per_graph
                        .iter()
                        .zip(&plans)
                        .zip(&width_sets)
                        .map(|((probe, plan), widths)| {
                            let probe = Arc::clone(probe);
                            let widths = widths.clone();
                            Arc::new(plan.clone().wrap_wide(
                                move |v: NodeId, rank: u32, width: u32| {
                                    if rank >= width || width > widths[v as usize] as u32 {
                                        probe.3.fetch_add(1, Ordering::SeqCst);
                                    }
                                    if rank == 0 {
                                        probe.0[v as usize].fetch_add(1, Ordering::SeqCst);
                                        let t = probe.1.fetch_add(1, Ordering::SeqCst);
                                        probe.2[v as usize].store(t, Ordering::SeqCst);
                                    }
                                },
                            )) as Arc<dyn Fn(NodeId, u32, u32) + Send + Sync>
                        })
                        .collect();
                    let (outcomes, shutdown) = std::thread::scope(|scope| {
                        let fleet = Fleet::new(
                            scope,
                            FleetConfig::new(execs)
                                .with_dispatch(mode)
                                .with_watchdog(Duration::from_secs(10)),
                        );
                        let handles: Vec<_> = graphs
                            .iter()
                            .zip(&level_sets)
                            .zip(&width_sets)
                            .zip(&works)
                            .map(|(((g, levels), widths), work)| {
                                fleet.submit_moldable(
                                    g,
                                    levels.clone(),
                                    widths.clone(),
                                    Arc::clone(work),
                                    None,
                                )
                            })
                            .collect();
                        if plans.iter().any(|p| p.cancel_after_us.is_some()) {
                            std::thread::sleep(Duration::from_micros(200));
                            for (h, plan) in handles.iter().zip(&plans) {
                                if plan.cancel_after_us.is_some() {
                                    h.cancel();
                                }
                            }
                        }
                        let outcomes: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
                        (outcomes, fleet.shutdown())
                    });
                    let counts: Vec<Vec<u32>> = per_graph
                        .iter()
                        .map(|p| p.0.iter().map(|c| c.load(Ordering::SeqCst)).collect())
                        .collect();
                    let stamps: Vec<Vec<u64>> = per_graph
                        .iter()
                        .map(|p| p.2.iter().map(|s| s.load(Ordering::SeqCst)).collect())
                        .collect();
                    let violations: Vec<u32> =
                        per_graph.iter().map(|p| p.3.load(Ordering::SeqCst)).collect();
                    let _ = tx.send((outcomes, counts, stamps, violations, shutdown));
                });
                let (outcomes, counts, stamps, violations, shutdown) =
                    match rx.recv_timeout(WATCHDOG) {
                        Ok(out) => out,
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            panic!("{tag}: no quiescence within {WATCHDOG:?} — gang hang")
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            panic!("{tag}: worker thread panicked inside the run")
                        }
                    };
                let mut expected_failed = 0u64;
                for (si, ((graph, plan), outcome)) in
                    graphs.iter().zip(&plans).zip(&outcomes).enumerate()
                {
                    let stag = format!("{tag}/s{si}");
                    assert_eq!(violations[si], 0, "{stag}: seat contract violated");
                    let c = &counts[si];
                    let st = &stamps[si];
                    for (v, &n) in c.iter().enumerate() {
                        assert!(n <= 1, "{stag}: node {v} led {n} gangs");
                        if n == 1 {
                            for &p in graph.preds(v as NodeId) {
                                assert_eq!(
                                    c[p as usize], 1,
                                    "{stag}: node {v} ran but its dep {p} never did"
                                );
                                assert!(
                                    st[p as usize] < st[v],
                                    "{stag}: dep violated {p} vs {v}"
                                );
                            }
                        }
                    }
                    match outcome {
                        Ok(r) => {
                            assert!(
                                plan.panic_at.is_none(),
                                "{stag}: panic plan completed: {plan:?}"
                            );
                            assert_eq!(r.records.len(), graph.len(), "{stag}: record count");
                            assert!(
                                c.iter().all(|&n| n == 1),
                                "{stag}: Ok session with missing ops"
                            );
                        }
                        Err(SessionError::OpPanicked { node, payload }) => {
                            expected_failed += 1;
                            assert_eq!(Some(*node), plan.panic_at, "{stag}: wrong blamed node");
                            assert!(
                                payload.contains(FaultPlan::PANIC_TAG),
                                "{stag}: foreign panic payload: {payload}"
                            );
                        }
                        Err(SessionError::Cancelled) => {
                            assert!(plan.cancel_after_us.is_some(), "{stag}: spurious cancel");
                        }
                        Err(other) => panic!("{stag}: unexpected terminal {other:?}"),
                    }
                }
                let totals = match shutdown {
                    Ok(t) => {
                        assert_eq!(
                            expected_failed, 0,
                            "{tag}: sessions failed but shutdown reported clean"
                        );
                        t
                    }
                    Err(e) => {
                        assert!(
                            e.panicked_threads.is_empty(),
                            "{tag}: fleet thread died: {:?}",
                            e.panicked_threads
                        );
                        assert_eq!(e.sessions_failed, expected_failed, "{tag}: failure count");
                        e.totals
                    }
                };
                assert_eq!(
                    totals.executor_threads, execs as u64,
                    "{tag}: executor threads leaked or respawned"
                );
                assert_eq!(
                    totals.sessions_completed
                        + totals.sessions_failed
                        + totals.sessions_cancelled
                        + totals.sessions_deadline_missed,
                    graphs.len() as u64,
                    "{tag}: session outcomes must conserve"
                );
            }
        }
    }
}

#[test]
fn stress_numa_mapped_fleet() {
    // the NUMA-ranked steal path under real concurrency: a 2-domain map
    // on 4 executors, same invariants, cross-domain accounting consistent
    let graph = Arc::new(fan(32));
    let mut rng = Rng::new(base_seed() ^ 0xD0);
    for iter in 0..ITERATIONS {
        let tag = format!("numa-fan/iter{iter}");
        let engine = ThreadedGraphi::new(4).with_numa(DomainMap::new(vec![0, 0, 1, 1], 0));
        let levels = seeded_levels(graph.len(), &mut rng);
        let outcome = run_with_watchdog(&graph, engine, levels, &tag);
        assert_invariants(&graph, &outcome, &tag);
    }
}

#[test]
fn stress_forced_alternating_phase_plan_transitions_without_deadlock() {
    // 1 → 32 → 1 at threshold 2 is narrow|wide|narrow: a forced c|d|c
    // plan must transition at *every* phase boundary (barrier + engine
    // switch) and still satisfy the invariants — the cross-phase barrier
    // is where a missed quiescence flag would deadlock, which the
    // watchdog converts into a bounded failure
    let graph = Arc::new(fan(32));
    let phases = graphi::graph::width_phases(&graph, 2);
    assert_eq!(phases.len(), 3);
    let mut rng = Rng::new(base_seed() ^ 0xA17);
    for iter in 0..ITERATIONS {
        for (first, second) in
            [(DispatchMode::Centralized, DispatchMode::Decentralized),
             (DispatchMode::Decentralized, DispatchMode::Centralized)]
        {
            let plan = PhasePlan { threshold: 2, modes: vec![first, second, first] };
            for &execs in &FLEETS {
                let tag = format!(
                    "phased-fan/iter{iter}/{execs}exec/{}-{}",
                    first.name(),
                    second.name()
                );
                let engine = ThreadedGraphi::new(execs).with_phase_plan(plan.clone());
                let levels = seeded_levels(graph.len(), &mut rng);
                let outcome = run_with_watchdog(&graph, engine, levels, &tag);
                assert_invariants(&graph, &outcome, &tag);
                assert_eq!(
                    outcome.mode_switches, 2,
                    "{tag}: alternating plan must switch at both boundaries"
                );
            }
        }
    }
}
