//! Runtime integration over real AOT artifacts (requires `make artifacts`).
//!
//! These tests exercise the full three-layer path: Pallas kernel → JAX
//! train step → HLO text → PJRT CPU client → Rust driver. They skip with a
//! notice when artifacts are absent so plain `cargo test` works before the
//! Python build step; `make test` always builds artifacts first.

use graphi::runtime::{ArtifactSet, LstmTrainer, PjrtRuntime, SyntheticCorpus};

fn artifacts() -> Option<ArtifactSet> {
    let dir = graphi::runtime::artifacts::default_dir();
    match ArtifactSet::load(&dir) {
        Ok(set) => Some(set),
        Err(e) => {
            eprintln!("skipping runtime integration test: {e}");
            None
        }
    }
}

#[test]
fn manifest_has_all_modules() {
    let Some(set) = artifacts() else { return };
    for name in ["train_step", "forward_loss", "lstm_cell"] {
        let m = set.module(name).unwrap();
        assert!(set.path_of(m).is_file(), "{name} HLO file missing");
        assert!(!m.inputs.is_empty());
    }
}

#[test]
fn lstm_cell_artifact_matches_closed_form() {
    // zero gates, c_prev = 1 ⇒ c_new = σ(forget_bias)·1 and
    // h_new = σ(0)·tanh(c_new) = 0.5·tanh(c_new): check the kernel artifact
    // computes the math the Pallas source promises, from Rust.
    let Some(set) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let module = rt.load(&set, "lstm_cell").unwrap();
    let batch = module.manifest.inputs[1][0];
    let hidden = module.manifest.inputs[1][1];
    let gates = vec![0.0f32; batch * 4 * hidden];
    let c_prev = vec![1.0f32; batch * hidden];
    let out = module.run_f32(&[gates, c_prev]).unwrap();
    let (h, c) = (&out[0], &out[1]);
    let sig1 = 1.0 / (1.0 + (-1.0f32).exp()); // forget bias = 1.0
    let expect_c = sig1;
    let expect_h = 0.5 * expect_c.tanh();
    for (&cv, &hv) in c.iter().zip(h.iter()) {
        assert!((cv - expect_c).abs() < 1e-5, "c {cv} vs {expect_c}");
        assert!((hv - expect_h).abs() < 1e-5, "h {hv} vs {expect_h}");
    }
}

#[test]
fn forward_loss_starts_near_uniform_entropy() {
    let Some(set) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let trainer = LstmTrainer::new(&rt, &set, 7).unwrap();
    let module = rt.load(&set, "forward_loss").unwrap();
    let batch = module.manifest.inputs[1][0];
    let window = module.manifest.inputs[1][1];
    let mut corpus = SyntheticCorpus::new(1, 100_000);
    let tokens = corpus.next_batch(batch, window - 1);
    // use the trainer's init params via a fresh trainer (same seed ⇒ same init)
    let params = {
        // re-derive deterministically: LstmTrainer::new(seed=7) twice gives
        // identical params; we read them via a 0-step "train"
        drop(trainer);
        let t2 = LstmTrainer::new(&rt, &set, 7).unwrap();
        // park: run forward through train-free module using t2's params —
        // LstmTrainer does not expose params, so replicate its init here
        let p = set.module("train_step").unwrap().inputs[0][0];
        let scale = *set.module("train_step").unwrap().meta.get("init_scale").unwrap_or(&0.1) as f32;
        let mut rng = graphi::util::rng::Rng::new(7);
        let _ = t2;
        (0..p).map(|_| (rng.f64() as f32 * 2.0 - 1.0) * scale).collect::<Vec<f32>>()
    };
    let out = module.run_f32(&[params, tokens]).unwrap();
    let loss = out[0][0];
    let uniform = (set.module("train_step").unwrap().meta["vocab"] as f32).ln();
    assert!(
        (loss - uniform).abs() < 1.0,
        "initial loss {loss} should be near ln(vocab) = {uniform}"
    );
}

#[test]
fn training_reduces_loss_through_pjrt() {
    let Some(set) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let mut trainer = LstmTrainer::new(&rt, &set, 42).unwrap();
    let report = trainer.train(30, 0xBEEF, 0).unwrap();
    assert_eq!(report.losses.len(), 30);
    assert!(
        report.final_loss() < report.initial_loss(),
        "loss did not fall: {} → {}",
        report.initial_loss(),
        report.final_loss()
    );
    assert!(report.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn train_step_is_deterministic() {
    let Some(set) = artifacts() else { return };
    let rt = PjrtRuntime::cpu().unwrap();
    let run = |seed| {
        let mut t = LstmTrainer::new(&rt, &set, seed).unwrap();
        let mut corpus = SyntheticCorpus::new(9, 100_000);
        let batch = corpus.next_batch(
            set.module("train_step").unwrap().meta["batch"] as usize,
            set.module("train_step").unwrap().meta["seq"] as usize,
        );
        t.step(batch).unwrap()
    };
    assert_eq!(run(5), run(5));
    assert_ne!(run(5), run(6));
}

#[test]
fn phased_gate_artifact_blends_states() {
    // fully-closed gate (leak only): c ≈ c_prev; fully-open needs exact
    // phase, so test the closed case which is robust.
    let Some(set) = artifacts() else { return };
    let Ok(m) = set.module("phased_gate") else {
        eprintln!("skipping: artifacts predate the phased_gate module");
        return;
    };
    let rt = PjrtRuntime::cpu().unwrap();
    let module = rt.load(&set, "phased_gate").unwrap();
    let batch = m.inputs[0][0];
    let hidden = m.inputs[0][1];
    let c_cand = vec![5.0f32; batch * hidden];
    let h_cand = vec![-5.0f32; batch * hidden];
    let c_prev = vec![1.0f32; batch * hidden];
    let h_prev = vec![0.0f32; batch * hidden];
    let tau = vec![2.0f32; hidden];
    let shift = vec![0.0f32; hidden];
    let time = vec![1.0f32]; // phi = 0.5 ⇒ closed (leak 0.001·0.5)
    let out = module
        .run_f32(&[c_cand, h_cand, c_prev, h_prev, tau, shift, time])
        .unwrap();
    let (c, h) = (&out[0], &out[1]);
    let k = 0.001f32 * 0.5;
    for (&cv, &hv) in c.iter().zip(h.iter()) {
        assert!((cv - (k * 5.0 + (1.0 - k) * 1.0)).abs() < 1e-5, "c {cv}");
        assert!((hv - (k * -5.0)).abs() < 1e-5, "h {hv}");
    }
}
