//! Property-based tests over random DAGs (testkit's proptest replacement).
//!
//! These are the invariants the paper's correctness rests on: every engine
//! must execute every DAG validly, never beat the critical-path/area lower
//! bound, and never lose to the sequential upper bound by more than
//! overhead.

use graphi::engine::{Engine, GraphiEngine, NaiveEngine, Policy, SequentialEngine, SimEnv};
use graphi::graph::levels::{critical_path_length, levels, makespan_lower_bound};
use graphi::graph::op::{EwKind, OpKind};
use graphi::graph::{Graph, GraphBuilder};
use graphi::util::testkit::{check, DagCase, DagGen, Gen, UsizeRange};

/// Materialize a testkit DAG description as a computation graph whose op
/// costs roughly follow the weights (weights scale element-wise sizes).
fn graph_of(case: &DagCase) -> Graph {
    let mut b = GraphBuilder::new();
    for i in 0..case.n {
        // mix op classes by index so random DAGs exercise GEMM + EW + tiny
        let kind = match i % 3 {
            0 => OpKind::MatMul { m: 32, k: 64 + (case.weights[i] as u64 % 256), n: 64 },
            1 => OpKind::Elementwise {
                n: 10_000 + (case.weights[i] * 1_000.0) as u64,
                arity: 2,
                kind: EwKind::Arith,
            },
            _ => OpKind::Scalar,
        };
        b.add(format!("n{i}"), kind);
    }
    for &(src, dst) in &case.edges {
        b.depend(src, dst);
    }
    b.build().expect("testkit DAGs are acyclic by construction")
}

#[test]
fn prop_all_engines_produce_valid_schedules() {
    let gen = DagGen::default();
    let env = SimEnv::knl_deterministic();
    check("valid schedules", &gen, 60, |case| {
        let g = graph_of(case);
        for engine in [
            Box::new(GraphiEngine::new(4, 8)) as Box<dyn Engine>,
            Box::new(NaiveEngine::new(4, 8)),
            Box::new(SequentialEngine::new(32)),
        ] {
            let r = engine.run(&g, &env);
            r.validate(&g).map_err(|e| format!("{}: {e}", engine.name()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_makespan_bounded_below_by_critical_path() {
    let gen = DagGen::default();
    let env = SimEnv::knl_deterministic();
    check("cp lower bound", &gen, 60, |case| {
        let g = graph_of(case);
        let durations: Vec<f64> = g
            .nodes()
            .iter()
            .map(|n| env.cost.duration_us(&n.kind, 8))
            .collect();
        // tiny ops run faster on the LW lane than the cost model's
        // duration; exclude them from the bound by flooring at tiny cost
        let adjusted: Vec<f64> = g
            .nodes()
            .iter()
            .zip(&durations)
            .map(|(n, &d)| if n.kind.is_tiny() { 0.0 } else { d })
            .collect();
        let bound = critical_path_length(&g, &adjusted);
        // stream stores legitimately beat the raw cost-model duration on
        // memory-bound element-wise ops; disable them so the bound applies
        let engine = GraphiEngine { stream_stores: false, ..GraphiEngine::new(4, 8) };
        let r = engine.run(&g, &env);
        if r.makespan_us + 1e-6 < bound {
            return Err(format!("makespan {} < cp bound {bound}", r.makespan_us));
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_never_loses_badly_to_sequential() {
    // Graphi with k executors must stay within dispatch overhead of the
    // sequential engine at the same team size (it can only reorder and
    // parallelize, both of which help or are neutral).
    let gen = DagGen { max_nodes: 30, edge_prob: 0.2, wmax: 50.0 };
    let env = SimEnv::knl_deterministic();
    check("parallel ≤ sequential + overhead", &gen, 40, |case| {
        let g = graph_of(case);
        let seq = SequentialEngine::new(8).run(&g, &env).makespan_us;
        let par = GraphiEngine::new(4, 8).run(&g, &env).makespan_us;
        // generous overhead allowance: scheduler costs + LW serialization
        if par > seq * 1.10 + 100.0 {
            return Err(format!("parallel {par} ≫ sequential {seq}"));
        }
        Ok(())
    });
}

#[test]
fn prop_levels_dominate_successors() {
    let gen = DagGen::default();
    check("level recurrence", &gen, 80, |case| {
        let g = graph_of(case);
        let l = levels(&g, &case.weights[..g.len()].to_vec());
        for v in 0..g.len() as u32 {
            for &s in g.succs(v) {
                let expect = case.weights[v as usize] + l[s as usize];
                if l[v as usize] + 1e-9 < expect {
                    return Err(format!("level({v}) < dur + level({s})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lower_bound_monotone_in_executors() {
    let gen = DagGen::default();
    check("bound monotone", &gen, 50, |case| {
        let g = graph_of(case);
        let w = &case.weights;
        for k in 1..8usize {
            if makespan_lower_bound(&g, w, k) < makespan_lower_bound(&g, w, k + 1) - 1e-9 {
                return Err(format!("bound increased from k={k} to k={}", k + 1));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_policies_all_valid_and_cp_competitive() {
    let gen = DagGen { max_nodes: 35, edge_prob: 0.15, wmax: 200.0 };
    let env = SimEnv::knl_deterministic();
    check("policy validity", &gen, 30, |case| {
        let g = graph_of(case);
        let mut spans = Vec::new();
        for policy in Policy::all() {
            let r = GraphiEngine::new(4, 8).with_policy(policy).run(&g, &env);
            r.validate(&g).map_err(|e| format!("{}: {e}", policy.name()))?;
            spans.push((policy, r.makespan_us));
        }
        let cp = spans
            .iter()
            .find(|(p, _)| *p == Policy::CriticalPathFirst)
            .unwrap()
            .1;
        let best = spans.iter().map(|(_, s)| *s).fold(f64::INFINITY, f64::min);
        // CP-first should never be far off the best policy on random DAGs
        if cp > best * 1.25 + 50.0 {
            return Err(format!("cp-first {cp} ≫ best {best}"));
        }
        Ok(())
    });
}

#[test]
fn prop_deterministic_replay() {
    let gen = DagGen::default();
    check("replay determinism", &gen, 30, |case| {
        let g = graph_of(case);
        let env = SimEnv::knl(1234);
        let a = GraphiEngine::new(4, 8).run(&g, &env);
        let b = GraphiEngine::new(4, 8).run(&g, &env);
        if a.makespan_us != b.makespan_us {
            return Err("same seed, different makespan".into());
        }
        if a.records.len() != b.records.len() {
            return Err("same seed, different record counts".into());
        }
        Ok(())
    });
}

#[test]
fn prop_testkit_shrinker_sane() {
    // meta-test: shrunken DAG cases keep their invariants
    let gen = DagGen::default();
    check("shrinker invariants", &UsizeRange(0, 500), 50, |&seed| {
        let mut rng = graphi::util::rng::Rng::new(seed as u64);
        let case = gen.generate(&mut rng);
        for s in gen.shrink(&case) {
            if s.weights.len() != s.n {
                return Err("weights out of sync".into());
            }
            for &(a, b) in &s.edges {
                if a >= b || (b as usize) >= s.n {
                    return Err(format!("bad edge {a}->{b}"));
                }
            }
        }
        Ok(())
    });
}
