//! Property-based tests over random DAGs (testkit's proptest replacement).
//!
//! These are the invariants the paper's correctness rests on: every engine
//! must execute every DAG validly, never beat the critical-path/area lower
//! bound, and never lose to the sequential upper bound by more than
//! overhead.

use graphi::engine::ready::ReadySet;
use graphi::engine::ring::SpscRing;
use graphi::engine::scheduler::IdleBitmap;
use graphi::engine::{Engine, GraphiEngine, NaiveEngine, Policy, SequentialEngine, SimEnv};
use graphi::graph::levels::{critical_path_length, levels, makespan_lower_bound};
use graphi::graph::op::{EwKind, OpKind};
use graphi::graph::{Graph, GraphBuilder};
use graphi::util::rng::Rng;
use graphi::util::testkit::{check, DagCase, DagGen, Gen, UsizeRange};

/// Materialize a testkit DAG description as a computation graph whose op
/// costs roughly follow the weights (weights scale element-wise sizes).
fn graph_of(case: &DagCase) -> Graph {
    let mut b = GraphBuilder::new();
    for i in 0..case.n {
        // mix op classes by index so random DAGs exercise GEMM + EW + tiny
        let kind = match i % 3 {
            0 => OpKind::MatMul { m: 32, k: 64 + (case.weights[i] as u64 % 256), n: 64 },
            1 => OpKind::Elementwise {
                n: 10_000 + (case.weights[i] * 1_000.0) as u64,
                arity: 2,
                kind: EwKind::Arith,
            },
            _ => OpKind::Scalar,
        };
        b.add(format!("n{i}"), kind);
    }
    for &(src, dst) in &case.edges {
        b.depend(src, dst);
    }
    b.build().expect("testkit DAGs are acyclic by construction")
}

#[test]
fn prop_all_engines_produce_valid_schedules() {
    let gen = DagGen::default();
    let env = SimEnv::knl_deterministic();
    check("valid schedules", &gen, 60, |case| {
        let g = graph_of(case);
        for engine in [
            Box::new(GraphiEngine::new(4, 8)) as Box<dyn Engine>,
            Box::new(NaiveEngine::new(4, 8)),
            Box::new(SequentialEngine::new(32)),
        ] {
            let r = engine.run(&g, &env);
            r.validate(&g).map_err(|e| format!("{}: {e}", engine.name()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_makespan_bounded_below_by_critical_path() {
    let gen = DagGen::default();
    let env = SimEnv::knl_deterministic();
    check("cp lower bound", &gen, 60, |case| {
        let g = graph_of(case);
        let durations: Vec<f64> = g
            .nodes()
            .iter()
            .map(|n| env.cost.duration_us(&n.kind, 8))
            .collect();
        // tiny ops run faster on the LW lane than the cost model's
        // duration; exclude them from the bound by flooring at tiny cost
        let adjusted: Vec<f64> = g
            .nodes()
            .iter()
            .zip(&durations)
            .map(|(n, &d)| if n.kind.is_tiny() { 0.0 } else { d })
            .collect();
        let bound = critical_path_length(&g, &adjusted);
        // stream stores legitimately beat the raw cost-model duration on
        // memory-bound element-wise ops; disable them so the bound applies
        let engine = GraphiEngine { stream_stores: false, ..GraphiEngine::new(4, 8) };
        let r = engine.run(&g, &env);
        if r.makespan_us + 1e-6 < bound {
            return Err(format!("makespan {} < cp bound {bound}", r.makespan_us));
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_never_loses_badly_to_sequential() {
    // Graphi with k executors must stay within dispatch overhead of the
    // sequential engine at the same team size (it can only reorder and
    // parallelize, both of which help or are neutral).
    let gen = DagGen { max_nodes: 30, edge_prob: 0.2, wmax: 50.0 };
    let env = SimEnv::knl_deterministic();
    check("parallel ≤ sequential + overhead", &gen, 40, |case| {
        let g = graph_of(case);
        let seq = SequentialEngine::new(8).run(&g, &env).makespan_us;
        let par = GraphiEngine::new(4, 8).run(&g, &env).makespan_us;
        // generous overhead allowance: scheduler costs + LW serialization
        if par > seq * 1.10 + 100.0 {
            return Err(format!("parallel {par} ≫ sequential {seq}"));
        }
        Ok(())
    });
}

#[test]
fn prop_levels_dominate_successors() {
    let gen = DagGen::default();
    check("level recurrence", &gen, 80, |case| {
        let g = graph_of(case);
        let l = levels(&g, &case.weights[..g.len()].to_vec());
        for v in 0..g.len() as u32 {
            for &s in g.succs(v) {
                let expect = case.weights[v as usize] + l[s as usize];
                if l[v as usize] + 1e-9 < expect {
                    return Err(format!("level({v}) < dur + level({s})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lower_bound_monotone_in_executors() {
    let gen = DagGen::default();
    check("bound monotone", &gen, 50, |case| {
        let g = graph_of(case);
        let w = &case.weights;
        for k in 1..8usize {
            if makespan_lower_bound(&g, w, k) < makespan_lower_bound(&g, w, k + 1) - 1e-9 {
                return Err(format!("bound increased from k={k} to k={}", k + 1));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_policies_all_valid_and_cp_competitive() {
    let gen = DagGen { max_nodes: 35, edge_prob: 0.15, wmax: 200.0 };
    let env = SimEnv::knl_deterministic();
    check("policy validity", &gen, 30, |case| {
        let g = graph_of(case);
        let mut spans = Vec::new();
        for policy in Policy::all() {
            let r = GraphiEngine::new(4, 8).with_policy(policy).run(&g, &env);
            r.validate(&g).map_err(|e| format!("{}: {e}", policy.name()))?;
            spans.push((policy, r.makespan_us));
        }
        let cp = spans
            .iter()
            .find(|(p, _)| *p == Policy::CriticalPathFirst)
            .unwrap()
            .1;
        let best = spans.iter().map(|(_, s)| *s).fold(f64::INFINITY, f64::min);
        // CP-first should never be far off the best policy on random DAGs
        if cp > best * 1.25 + 50.0 {
            return Err(format!("cp-first {cp} ≫ best {best}"));
        }
        Ok(())
    });
}

#[test]
fn prop_deterministic_replay() {
    let gen = DagGen::default();
    check("replay determinism", &gen, 30, |case| {
        let g = graph_of(case);
        let env = SimEnv::knl(1234);
        let a = GraphiEngine::new(4, 8).run(&g, &env);
        let b = GraphiEngine::new(4, 8).run(&g, &env);
        if a.makespan_us != b.makespan_us {
            return Err("same seed, different makespan".into());
        }
        if a.records.len() != b.records.len() {
            return Err("same seed, different record counts".into());
        }
        Ok(())
    });
}

/// Reference pop for the deterministic policies: scan the live set and
/// remove the entry the policy semantics promise (max/min priority with
/// FIFO tie-break on push order, plain FIFO, plain LIFO).
fn model_pop(policy: Policy, model: &mut Vec<(f64, u64, u32)>) -> u32 {
    let idx = match policy {
        Policy::CriticalPathFirst => {
            let mut best = 0;
            for i in 1..model.len() {
                let (p, s, _) = model[i];
                let (bp, bs, _) = model[best];
                if p > bp || (p == bp && s < bs) {
                    best = i;
                }
            }
            best
        }
        Policy::AntiCritical => {
            let mut best = 0;
            for i in 1..model.len() {
                let (p, s, _) = model[i];
                let (bp, bs, _) = model[best];
                if p < bp || (p == bp && s < bs) {
                    best = i;
                }
            }
            best
        }
        Policy::Fifo => 0,
        Policy::Lifo => model.len() - 1,
        Policy::Random => unreachable!("random handled by the mirrored-rng test"),
    };
    model.remove(idx).2
}

#[test]
fn prop_ready_set_matches_reference_order() {
    // random interleaved push/pop streams: the packed d-ary heap (and the
    // queue/stack policies) must pop in exactly the order a brute-force
    // scan of (priority, push-seq) produces. Priorities come from a coarse
    // grid, so exact ties are frequent (exercising the FIFO tie-break)
    // while distinct values survive the packed key's 32-bit quantization.
    for seed in 0..25u64 {
        let mut gen_rng = Rng::new(seed.wrapping_mul(0x9E37) + 1);
        let n: usize = 150;
        let levels: Vec<f64> = (0..n).map(|_| gen_rng.below(40) as f64 * 16.0).collect();
        for &policy in
            &[Policy::CriticalPathFirst, Policy::AntiCritical, Policy::Fifo, Policy::Lifo]
        {
            let mut rs = ReadySet::new(policy, levels.clone(), seed);
            let mut model: Vec<(f64, u64, u32)> = Vec::new();
            let mut op_rng = Rng::new(seed ^ 0xABCD);
            let mut seq = 0u64;
            let mut next_node = 0u32;
            let mut popped = Vec::new();
            let mut expected = Vec::new();
            for _ in 0..3 * n {
                let can_push = (next_node as usize) < n;
                if can_push && (model.is_empty() || op_rng.chance(0.55)) {
                    rs.push(next_node);
                    model.push((levels[next_node as usize], seq, next_node));
                    seq += 1;
                    next_node += 1;
                } else if !model.is_empty() {
                    popped.push(rs.pop().expect("set non-empty per model"));
                    expected.push(model_pop(policy, &mut model));
                } else {
                    break;
                }
            }
            while let Some(v) = rs.pop() {
                popped.push(v);
                expected.push(model_pop(policy, &mut model));
            }
            assert!(model.is_empty(), "{}: model drained with set", policy.name());
            assert!(rs.is_empty(), "{}: set drained with model", policy.name());
            assert_eq!(popped, expected, "policy {} seed {seed}", policy.name());
        }
    }
}

#[test]
fn prop_ready_set_random_policy_mirrors_seeded_rng() {
    // the Random policy must consume exactly one `range(0, len)` draw per
    // pop from a generator seeded with the ReadySet seed — the contract
    // `deterministic per seed` rests on
    for seed in 0..10u64 {
        let n = 64u32;
        let mut rs = ReadySet::new(Policy::Random, vec![0.0; n as usize], seed);
        let mut mirror: Vec<u32> = Vec::new();
        let mut mirror_rng = Rng::new(seed);
        for i in 0..n {
            rs.push(i);
            mirror.push(i);
        }
        let mut out = Vec::new();
        let mut expect = Vec::new();
        while let Some(v) = rs.pop() {
            out.push(v);
            let i = mirror_rng.range(0, mirror.len());
            expect.push(mirror.swap_remove(i));
        }
        assert_eq!(out.len(), n as usize);
        assert_eq!(out, expect, "seed {seed}");
    }
}

#[test]
fn prop_spsc_ring_batch_two_thread_stress() {
    // producer pushes variable-size batches, consumer drains in batches;
    // every item must arrive exactly once, in order, across real threads
    let ring = SpscRing::<u64>::new(64);
    let n = 50_000u64;
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut chunk_rng = Rng::new(7);
            let mut next = 0u64;
            while next < n {
                let hi = (next + 1 + chunk_rng.below(31)).min(n);
                let mut batch = next..hi;
                let pushed = ring.push_batch(&mut batch) as u64;
                next += pushed;
                if pushed == 0 {
                    std::hint::spin_loop();
                    std::thread::yield_now();
                }
            }
        });
        let mut out: Vec<u64> = Vec::with_capacity(32);
        let mut expected = 0u64;
        while expected < n {
            out.clear();
            if ring.pop_batch(&mut out, 32) == 0 {
                std::hint::spin_loop();
                std::thread::yield_now();
                continue;
            }
            for &v in &out {
                assert_eq!(v, expected, "out-of-order item from batch pop");
                expected += 1;
            }
        }
    });
    assert!(ring.is_empty());
}

#[test]
fn prop_spsc_ring_mixed_single_and_batch_two_thread() {
    // alternate single-item and batched operations on both sides; order
    // and exactly-once delivery must survive the mix
    let ring = SpscRing::<u64>::new(16);
    let n = 20_000u64;
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut next = 0u64;
            while next < n {
                let advanced = if next % 3 == 0 {
                    let hi = (next + 5).min(n);
                    let mut batch = next..hi;
                    ring.push_batch(&mut batch) as u64
                } else {
                    match ring.push(next) {
                        Ok(()) => 1,
                        Err(_) => 0,
                    }
                };
                next += advanced;
                if advanced == 0 {
                    std::thread::yield_now();
                }
            }
        });
        let mut out: Vec<u64> = Vec::new();
        let mut expected = 0u64;
        while expected < n {
            let got = if expected % 2 == 0 {
                out.clear();
                let popped = ring.pop_batch(&mut out, 7);
                for &v in &out {
                    assert_eq!(v, expected);
                    expected += 1;
                }
                popped > 0
            } else if let Some(v) = ring.pop() {
                assert_eq!(v, expected);
                expected += 1;
                true
            } else {
                false
            };
            if !got {
                std::thread::yield_now();
            }
        }
    });
    assert!(ring.is_empty());
}

/// Drive an [`IdleBitmap`] and a naive `Vec<bool>` reference through the
/// same random busy/idle walk, comparing every query after every step.
fn idle_bitmap_walk(n: usize, seed: u64, steps: usize) -> Result<(), String> {
    let mut bits = IdleBitmap::new(n);
    let mut reference = vec![true; n];
    let mut rng = Rng::new(seed);
    for step in 0..steps {
        let ref_first = reference.iter().position(|&b| b);
        if bits.first_idle() != ref_first {
            return Err(format!(
                "n={n} step {step}: first_idle {:?} vs reference {ref_first:?}",
                bits.first_idle()
            ));
        }
        let ref_count = reference.iter().filter(|&&b| b).count();
        if bits.count_idle() != ref_count {
            return Err(format!(
                "n={n} step {step}: count_idle {} vs reference {ref_count}",
                bits.count_idle()
            ));
        }
        if bits.any_idle() != (ref_count > 0) {
            return Err(format!("n={n} step {step}: any_idle disagrees"));
        }
        if bits.executors() != n {
            return Err(format!("n={n}: executors() reported {}", bits.executors()));
        }
        // flip a random executor (set_busy/set_idle contract: only valid
        // transitions, as the engines use it)
        let e = rng.range(0, n);
        if reference[e] {
            bits.set_busy(e);
            reference[e] = false;
        } else {
            bits.set_idle(e);
            reference[e] = true;
        }
        if bits.is_idle(e) != reference[e] {
            return Err(format!("n={n} step {step}: is_idle({e}) disagrees after flip"));
        }
    }
    Ok(())
}

#[test]
fn prop_idle_bitmap_matches_bool_vec_reference() {
    check("idle bitmap vs Vec<bool>", &UsizeRange(1, 128), 80, |&n| {
        idle_bitmap_walk(n, n as u64 ^ 0xB17B17, 300)
    });
}

#[test]
fn idle_bitmap_reference_walk_at_the_128_boundary() {
    // the u128 backing store's edge sizes, checked exhaustively: 127 (top
    // bit unused), 128 (the `1 << n` overflow case), and 64 (the u64 line)
    for n in [63, 64, 65, 127, 128] {
        idle_bitmap_walk(n, 0xF00D + n as u64, 2_000).unwrap();
    }
}

/// Reference longest-path computation for `levels`: memoized recursion
/// over successors, structurally independent of the reverse-topological
/// sweep in `graph::levels`.
fn ref_longest_path(graph: &Graph, durations: &[f64]) -> Vec<f64> {
    fn go(v: u32, graph: &Graph, durations: &[f64], memo: &mut [Option<f64>]) -> f64 {
        if let Some(x) = memo[v as usize] {
            return x;
        }
        let mut best = 0.0f64;
        for &s in graph.succs(v) {
            best = best.max(go(s, graph, durations, memo));
        }
        let value = durations[v as usize] + best;
        memo[v as usize] = Some(value);
        value
    }
    let mut memo = vec![None; graph.len()];
    (0..graph.len() as u32)
        .map(|v| go(v, graph, durations, &mut memo))
        .collect()
}

#[test]
fn prop_levels_match_reference_longest_path() {
    let gen = DagGen::default();
    check("levels vs reference longest path", &gen, 80, |case| {
        let g = graph_of(case);
        let computed = levels(&g, &case.weights);
        let reference = ref_longest_path(&g, &case.weights);
        for v in 0..g.len() {
            let (a, b) = (computed[v], reference[v]);
            if (a - b).abs() > 1e-9 * b.abs().max(1.0) {
                return Err(format!("level({v}) = {a} but reference longest path = {b}"));
            }
        }
        let cp = critical_path_length(&g, &case.weights);
        let max_ref = reference.iter().cloned().fold(0.0f64, f64::max);
        if (cp - max_ref).abs() > 1e-9 * max_ref.max(1.0) {
            return Err(format!("critical_path_length {cp} vs reference max {max_ref}"));
        }
        Ok(())
    });
}

#[test]
fn prop_testkit_shrinker_sane() {
    // meta-test: shrunken DAG cases keep their invariants
    let gen = DagGen::default();
    check("shrinker invariants", &UsizeRange(0, 500), 50, |&seed| {
        let mut rng = graphi::util::rng::Rng::new(seed as u64);
        let case = gen.generate(&mut rng);
        for s in gen.shrink(&case) {
            if s.weights.len() != s.n {
                return Err("weights out of sync".into());
            }
            for &(a, b) in &s.edges {
                if a >= b || (b as usize) >= s.n {
                    return Err(format!("bad edge {a}->{b}"));
                }
            }
        }
        Ok(())
    });
}

/// Brute-force reference for NUMA-aware victim ranking: scan every
/// victim's exposed top, pick the max-key victim **within the stealer's
/// domain**, and go cross-domain only when the local domain is dry or a
/// remote top's *level* (key high half) exceeds the local best's by more
/// than the margin — first victim wins exact key ties. This restates the
/// `steal_highest_numa` contract independently of its implementation.
fn ref_numa_choice(
    tops: &[Option<u64>],
    me: usize,
    map: &graphi::engine::DomainMap,
) -> Option<(usize, graphi::engine::Acquire)> {
    use graphi::engine::worksteal::entry_level;
    use graphi::engine::Acquire;
    let mut best_local: Option<(usize, u64)> = None;
    let mut best_remote: Option<(usize, u64)> = None;
    for (v, top) in tops.iter().enumerate() {
        if v == me {
            continue;
        }
        let Some(k) = *top else { continue };
        let slot = if map.same_domain(me, v) { &mut best_local } else { &mut best_remote };
        if slot.map_or(true, |(_, bk)| k > bk) {
            *slot = Some((v, k));
        }
    }
    match (best_local, best_remote) {
        (None, None) => None,
        (Some((v, _)), None) => Some((v, Acquire::StealLocalDomain)),
        (None, Some((v, _))) => Some((v, Acquire::StealCrossDomain)),
        (Some((lv, lk)), Some((rv, rk))) => {
            if entry_level(rk) > entry_level(lk).saturating_add(map.cross_margin) {
                Some((rv, Acquire::StealCrossDomain))
            } else {
                Some((lv, Acquire::StealLocalDomain))
            }
        }
    }
}

#[test]
fn prop_numa_victim_ranking_matches_bruteforce_reference() {
    // random deque states (random key piles per victim) × random domain
    // maps × random margins: draining steal_highest_numa single-threaded
    // must pick exactly the victim/kind the brute-force rule picks, every
    // step until all deques are dry
    use graphi::engine::worksteal::{steal_highest_numa, WorkStealDeque};
    use graphi::engine::DomainMap;
    use std::collections::VecDeque;
    for seed in 0..40u64 {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B9) + 7);
        let n = rng.range(2, 7);
        let me = rng.range(0, n);
        let domains: Vec<u32> = (0..n).map(|_| rng.below(3) as u32).collect();
        let margin = rng.below(3) as u32;
        let map = DomainMap::new(domains, margin);
        let deques: Vec<WorkStealDeque> = (0..n).map(|_| WorkStealDeque::new(32)).collect();
        // mirror of each deque as a FIFO (steal end = front)
        let mut mirror: Vec<VecDeque<u64>> = (0..n).map(|_| VecDeque::new()).collect();
        for v in 0..n {
            for _ in 0..rng.range(0, 6) {
                // small level space so level ties (the interesting case
                // for domain preference) are frequent
                let key = (rng.below(4) << 32) | rng.below(1000);
                deques[v].push(key).unwrap();
                mirror[v].push_back(key);
            }
        }
        loop {
            let tops: Vec<Option<u64>> = mirror.iter().map(|m| m.front().copied()).collect();
            let expected = ref_numa_choice(&tops, me, &map);
            let got = steal_highest_numa(&deques, me, &map);
            match (expected, got) {
                (None, None) => break,
                (Some((victim, kind)), Some((key, got_kind))) => {
                    let want_key = mirror[victim].pop_front().unwrap();
                    assert_eq!(
                        (key, got_kind),
                        (want_key, kind),
                        "seed {seed}: me={me} domains/margin {map:?} tops {tops:?}"
                    );
                }
                (e, g) => panic!("seed {seed}: reference {e:?} vs implementation {g:?}"),
            }
        }
        assert!(deques.iter().all(|d| d.is_empty()), "seed {seed}: drained together");
    }
}

#[test]
fn prop_backoff_state_machine_walks_its_limits() {
    // the spin→yield→park walk against a plain counter model, across
    // random limits and random reset points
    use graphi::engine::{Backoff, BackoffStage};
    check("backoff stage walk", &UsizeRange(0, 500), 60, |&seed| {
        let mut rng = Rng::new(seed as u64 ^ 0xBACC0FF);
        let spin = rng.range(0, 10) as u32;
        let yields = rng.range(0, 10) as u32;
        let mut b = Backoff::with_limits(spin, yields);
        let mut attempts = 0u32;
        for step in 0..200 {
            let expected = if attempts < spin {
                BackoffStage::Spin
            } else if attempts < spin + yields {
                BackoffStage::Yield
            } else {
                BackoffStage::Park
            };
            if b.stage() != expected {
                return Err(format!(
                    "seed {seed} step {step}: stage {:?} vs model {expected:?} at {attempts}",
                    b.stage()
                ));
            }
            if b.next() != expected {
                return Err(format!("seed {seed} step {step}: next() disagrees with stage()"));
            }
            if expected != BackoffStage::Park {
                attempts += 1;
            }
            if rng.chance(0.1) {
                b.reset();
                attempts = 0;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_park_never_sleeps_through_a_post_prepare_notify() {
    // the lost-wakeup race, swept across interleaving offsets: however
    // many notifies land between the prepare (registration + epoch
    // observation) and the park, the park must return immediately (the
    // registered waiter forces each notify to bump the epoch, and the
    // moved epoch refuses the sleep)
    use graphi::engine::EventCounter;
    use std::time::{Duration, Instant};
    let ec = EventCounter::new();
    for notifies in 1..20u64 {
        let observed = ec.prepare();
        for _ in 0..notifies {
            ec.notify(); // the "push between re-scan and park"
        }
        let t0 = Instant::now();
        let slept = ec.park(observed, Duration::from_secs(5));
        assert!(!slept, "{notifies} post-prepare notifies must void the observation");
        assert!(t0.elapsed() < Duration::from_secs(1));
        assert_eq!(ec.waiters(), 0);
    }
}

/// Reference model for the work-stealing deque: a `VecDeque` where the
/// owner pushes/pops at the back (LIFO) and thieves take from the front
/// (the high-priority/FIFO end). Single-threaded, so the deque must agree
/// with the model exactly, operation by operation.
#[test]
fn prop_worksteal_deque_matches_vecdeque_reference() {
    use graphi::engine::worksteal::{Steal, WorkStealDeque};
    use graphi::util::testkit::VecOf;
    use std::collections::VecDeque;

    // command stream: 0..=5 → push (values from a counter), 6..=8 → owner
    // pop, 9..=11 → steal, biased toward pushes so the deque fills up and
    // wraps
    let gen = VecOf { inner: UsizeRange(0, 11), min_len: 1, max_len: 400 };
    check("worksteal deque vs VecDeque reference", &gen, 60, |cmds| {
        let capacity = 16usize;
        let deque = WorkStealDeque::new(capacity);
        let mut reference: VecDeque<u64> = VecDeque::new();
        let mut next_value = 1u64;
        for (step, &cmd) in cmds.iter().enumerate() {
            match cmd {
                0..=5 => {
                    let v = next_value;
                    next_value += 1;
                    let pushed = deque.push(v).is_ok();
                    let ref_pushed = reference.len() < deque.capacity();
                    if pushed != ref_pushed {
                        return Err(format!(
                            "step {step}: push({v}) accepted={pushed}, reference={ref_pushed}"
                        ));
                    }
                    if ref_pushed {
                        reference.push_back(v);
                    }
                }
                6..=8 => {
                    let got = deque.pop();
                    let want = reference.pop_back();
                    if got != want {
                        return Err(format!("step {step}: pop = {got:?}, reference = {want:?}"));
                    }
                }
                _ => {
                    let got = match deque.steal() {
                        Steal::Success(v) => Some(v),
                        Steal::Empty => None,
                        Steal::Retry => {
                            return Err(format!(
                                "step {step}: Retry without concurrency"
                            ))
                        }
                    };
                    let want = reference.pop_front();
                    if got != want {
                        return Err(format!("step {step}: steal = {got:?}, reference = {want:?}"));
                    }
                }
            }
            if deque.len() != reference.len() {
                return Err(format!(
                    "step {step}: len {} vs reference {}",
                    deque.len(),
                    reference.len()
                ));
            }
            let top = deque.peek_top();
            let want_top = reference.front().copied();
            if top != want_top {
                return Err(format!(
                    "step {step}: peek_top {top:?} vs reference front {want_top:?}"
                ));
            }
        }
        // drain from both ends alternately; every survivor must match
        let mut from_top = true;
        while let Some(want) = if from_top { reference.pop_front() } else { reference.pop_back() } {
            let got = if from_top {
                match deque.steal() {
                    Steal::Success(v) => Some(v),
                    _ => None,
                }
            } else {
                deque.pop()
            };
            if got != Some(want) {
                return Err(format!("drain: got {got:?}, want {want}"));
            }
            from_top = !from_top;
        }
        if !deque.is_empty() {
            return Err("deque not empty after reference drained".into());
        }
        Ok(())
    });
}
