//! Cross-engine differential suite: every engine — sequential, naive,
//! tensorflow-like, graphi, dynamic, heterogeneous — must agree on the
//! *semantics* of executing a random DAG even though their scheduling
//! differs:
//!
//! 1. every operation executes **exactly once**, in a dependency-respecting
//!    order with no per-executor overlap;
//! 2. a parallel engine's makespan never exceeds "the sequential one": the
//!    serialization of its own schedule (Σ of its measured op durations
//!    plus its own accounted scheduling overheads) — parallelism may only
//!    overlap work, never invent time;
//! 3. for engines whose per-op cost basis matches the sequential engine at
//!    the same team size (graphi, naive, dynamic), the makespan is also
//!    bounded by the *sequential engine's* makespan plus overhead.
//!    (tensorflow-like prices MKL kernels + Eigen chunking + unpinned
//!    threads, and heterogeneous mixes team sizes, so a same-team
//!    sequential baseline does not exist for them — invariant 2 is their
//!    differential bound.)
//!
//! Failures shrink to a minimal DAG and report the replay seed via
//! `testkit::check` (set `GRAPHI_TEST_SEED` to reproduce).

use graphi::engine::{
    DispatchMode, DynamicFleetEngine, Engine, GraphiEngine, HeterogeneousEngine, NaiveEngine,
    PhasePlan, RunResult, SequentialEngine, SimEnv, TensorFlowLikeEngine,
};
use graphi::graph::op::{EwKind, OpKind};
use graphi::graph::{Graph, GraphBuilder};
use graphi::util::testkit::{check, DagCase, DagGen};

/// Materialize a testkit DAG as a computation graph mixing GEMM,
/// element-wise and tiny ops (weights scale the element-wise sizes).
fn graph_of(case: &DagCase) -> Graph {
    let mut b = GraphBuilder::new();
    for i in 0..case.n {
        let kind = match i % 3 {
            0 => OpKind::MatMul { m: 32, k: 64 + (case.weights[i] as u64 % 256), n: 64 },
            1 => OpKind::Elementwise {
                n: 10_000 + (case.weights[i] * 1_000.0) as u64,
                arity: 2,
                kind: EwKind::Arith,
            },
            _ => OpKind::Scalar,
        };
        b.add(format!("n{i}"), kind);
    }
    for &(src, dst) in &case.edges {
        b.depend(src, dst);
    }
    b.build().expect("testkit DAGs are acyclic by construction")
}

/// All engines at comparable scale. Sequential runs one 8-thread
/// executor; the matched-team parallel engines split the same team size
/// across 4 executors. Graphi appears in both dispatch modes so the
/// centralized and decentralized schedulers stay differentially testable.
fn engines() -> Vec<Box<dyn Engine>> {
    vec![
        Box::new(SequentialEngine::new(8)),
        Box::new(GraphiEngine::new(4, 8)),
        Box::new(GraphiEngine::new(4, 8).with_dispatch(DispatchMode::Decentralized)),
        Box::new(NaiveEngine::new(4, 8)),
        Box::new(TensorFlowLikeEngine::new(4, 8)),
        Box::new(DynamicFleetEngine::new((4, 8), (8, 4))),
        Box::new(HeterogeneousEngine::paper_default()),
    ]
}

/// Every node appears exactly once in the records.
fn exactly_once(graph: &Graph, result: &RunResult) -> Result<(), String> {
    if result.records.len() != graph.len() {
        return Err(format!(
            "{} records for {} ops",
            result.records.len(),
            graph.len()
        ));
    }
    let mut seen = vec![0u32; graph.len()];
    for r in &result.records {
        let idx = r.node as usize;
        if idx >= graph.len() {
            return Err(format!("record for unknown node {}", r.node));
        }
        seen[idx] += 1;
    }
    if let Some((node, &count)) = seen.iter().enumerate().find(|(_, &c)| c != 1) {
        return Err(format!("node {node} executed {count} times"));
    }
    Ok(())
}

/// Upper bound on a run's makespan: serializing its own schedule. Sum of
/// measured op durations plus every overhead the engine accounts —
/// scheduler decisions, queue contention (incl. Eigen chunk waves and the
/// dynamic engine's team-resize pause), and a per-dispatch allowance for
/// the base queue/dispatch costs that are folded into timestamps rather
/// than metrics.
fn serialization_bound(env: &SimEnv, result: &RunResult) -> f64 {
    let serial: f64 = result.records.iter().map(|r| r.end_us - r.start_us).sum();
    let cal = env.calibration();
    let per_dispatch = cal.queue_base_us + cal.graphi_dispatch_us;
    serial
        + result.metrics.scheduler_busy_us
        + result.metrics.contention_us
        + result.metrics.dispatches as f64 * per_dispatch
        + 100.0
}

#[test]
fn prop_every_engine_executes_each_op_exactly_once_in_dep_order() {
    let gen = DagGen::default();
    let env = SimEnv::knl_deterministic();
    check("exactly-once + dependency order", &gen, 40, |case| {
        let g = graph_of(case);
        for engine in engines() {
            let r = engine.run(&g, &env);
            exactly_once(&g, &r).map_err(|e| format!("{}: {e}", engine.name()))?;
            // validate_records: dependency order + per-executor non-overlap
            r.validate(&g).map_err(|e| format!("{}: {e}", engine.name()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_makespan_never_exceeds_own_serialization() {
    let gen = DagGen::default();
    let env = SimEnv::knl_deterministic();
    check("makespan ≤ serialized schedule", &gen, 40, |case| {
        let g = graph_of(case);
        for engine in engines() {
            let r = engine.run(&g, &env);
            let bound = serialization_bound(&env, &r);
            if r.makespan_us > bound {
                return Err(format!(
                    "{}: makespan {} exceeds serialization bound {bound}",
                    engine.name(),
                    r.makespan_us
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_both_dispatch_modes_agree_on_random_dags() {
    // the PR-3 acceptance invariant: centralized and decentralized Graphi
    // run the same random DAGs and must agree on the *semantics* — every
    // op exactly once, dependency order respected, and each mode's
    // makespan within its own serialization bound (parallelism + stealing
    // may only overlap work, never invent time)
    let gen = DagGen::default();
    let env = SimEnv::knl_deterministic();
    check("centralized ≡ decentralized semantics", &gen, 40, |case| {
        let g = graph_of(case);
        for mode in DispatchMode::ALL {
            let engine = GraphiEngine::new(4, 8).with_dispatch(mode);
            let r = engine.run(&g, &env);
            exactly_once(&g, &r).map_err(|e| format!("{}: {e}", engine.name()))?;
            r.validate(&g).map_err(|e| format!("{}: {e}", engine.name()))?;
            let bound = serialization_bound(&env, &r);
            if r.makespan_us > bound {
                return Err(format!(
                    "{}: makespan {} exceeds own serialization bound {bound}",
                    engine.name(),
                    r.makespan_us
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_phase_mode_schedules_agree_with_uniform_runs() {
    // per-phase dispatch on random DAGs: every phased plan (both
    // alternating parities) must agree with the pure-centralized and
    // pure-decentralized runs on the semantics — exactly-once + dependency
    // order — and its mode transitions must match the plan exactly
    let gen = DagGen::default();
    let env = SimEnv::knl_deterministic();
    check("phased ≡ uniform semantics", &gen, 30, |case| {
        let g = graph_of(case);
        let threshold = 3;
        let n_phases = graphi::graph::width_phases(&g, threshold).len();
        // the two uniform baselines the phased runs must agree with
        for mode in DispatchMode::ALL {
            let r = GraphiEngine::new(4, 8).with_dispatch(mode).run(&g, &env);
            exactly_once(&g, &r).map_err(|e| format!("uniform {}: {e}", mode.name()))?;
            r.validate(&g).map_err(|e| format!("uniform {}: {e}", mode.name()))?;
        }
        for start in DispatchMode::ALL {
            let modes: Vec<DispatchMode> = (0..n_phases)
                .map(|i| if i % 2 == 0 { start } else { start.other() })
                .collect();
            let plan = PhasePlan { threshold, modes };
            let expected_switches = plan.mode_switches();
            let engine = GraphiEngine::new(4, 8).with_phase_plan(plan);
            let r = engine.run(&g, &env);
            exactly_once(&g, &r).map_err(|e| format!("phased[{}]: {e}", start.name()))?;
            r.validate(&g).map_err(|e| format!("phased[{}]: {e}", start.name()))?;
            if r.metrics.mode_switches != expected_switches {
                return Err(format!(
                    "phased[{}]: {} mode switches, plan promises {expected_switches}",
                    start.name(),
                    r.metrics.mode_switches
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_matched_team_parallel_never_exceeds_sequential() {
    // graphi (both dispatch modes)/naive/dynamic at 8-thread teams price
    // each op exactly like the 8-thread sequential engine, so overlapping
    // can only help; the allowance covers their accounted overheads
    // (dynamic's team resize lands in contention_us) plus scheduling costs.
    let gen = DagGen::default();
    let env = SimEnv::knl_deterministic();
    check("parallel ≤ matched sequential", &gen, 40, |case| {
        let g = graph_of(case);
        let seq = SequentialEngine::new(8).run(&g, &env).makespan_us;
        let parallel: Vec<Box<dyn Engine>> = vec![
            Box::new(GraphiEngine::new(4, 8)),
            Box::new(GraphiEngine::new(4, 8).with_dispatch(DispatchMode::Decentralized)),
            Box::new(NaiveEngine::new(4, 8)),
            Box::new(DynamicFleetEngine::new((4, 8), (8, 4))),
        ];
        for engine in parallel {
            let r = engine.run(&g, &env);
            let cap = seq * 1.10 + r.metrics.contention_us + r.metrics.scheduler_busy_us + 100.0;
            if r.makespan_us > cap {
                return Err(format!(
                    "{}: makespan {} vs sequential {seq} (cap {cap})",
                    engine.name(),
                    r.makespan_us
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn differential_holds_on_the_paper_models_too() {
    // the random-DAG invariants, spot-checked on two real model graphs
    use graphi::models::{self, ModelKind, ModelSize};
    let env = SimEnv::knl_deterministic();
    for kind in [ModelKind::Lstm, ModelKind::PathNet] {
        let g = models::build(kind, ModelSize::Small);
        for engine in engines() {
            let r = engine.run(&g, &env);
            exactly_once(&g, &r)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", kind.name(), engine.name()));
            r.validate(&g)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", kind.name(), engine.name()));
            let bound = serialization_bound(&env, &r);
            assert!(
                r.makespan_us <= bound,
                "{}/{}: makespan {} exceeds serialization bound {bound}",
                kind.name(),
                engine.name(),
                r.makespan_us
            );
        }
    }
}
