//! Autotuner acceptance: the successive-halving search must find a config
//! whose simulated makespan is within 5 % of the exhaustive sweep's best
//! while spending strictly fewer profiling iterations, and a second
//! invocation must load the persisted tuning artifact without
//! re-searching.

use graphi::engine::{Autotuner, DispatchMode, Engine, GraphiEngine, PhasePlan, Profiler, SimEnv};
use graphi::models::{self, ModelKind, ModelSize};
use graphi::runtime::artifacts::{
    autotune_or_load, tuning_path, ArtifactError, MachineKey, TuneOutcome, TuningArtifact,
    TUNING_FORMAT_VERSION,
};

/// The §7.3 extras both search strategies seed in (9 fleet shapes).
const EXTRAS: [(usize, usize); 2] = [(3, 21), (6, 10)];

/// The PR-3 default: 9 fleet shapes × 2 dispatch modes.
fn tuner() -> Autotuner {
    Autotuner { extra_configs: EXTRAS.to_vec(), ..Default::default() }
}

/// The PR-2 search: same fleet shapes, centralized dispatch only — what
/// the flat profiler sweep is an apples-to-apples baseline for.
fn centralized_tuner() -> Autotuner {
    Autotuner {
        extra_configs: EXTRAS.to_vec(),
        dispatch_modes: vec![DispatchMode::Centralized],
        ..Default::default()
    }
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("graphi-autotune-test-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn search_within_5pct_of_exhaustive_with_strictly_fewer_iterations() {
    // centralized axis only: the flat sweep baseline only measures
    // centralized configs, so that is the fair iteration comparison
    let g = models::build(ModelKind::Lstm, ModelSize::Small);
    let env = SimEnv::knl_deterministic();
    let report = centralized_tuner().search(&g, &env);

    // the flat §4.2 sweep at its default fidelity (3 iterations/candidate)
    let profiler = Profiler { iterations: 3, worker_cores: 64, extra_configs: EXTRAS.to_vec() };
    let exhaustive = profiler.profile(&g, &env);
    let exhaustive_iters = profiler.candidates().len() * profiler.iterations;

    assert!(
        report.total_profile_iterations < exhaustive_iters,
        "search spent {} iterations, exhaustive sweep {exhaustive_iters}",
        report.total_profile_iterations
    );
    // …and also fewer than an exhaustive sweep at the search's own final fidelity
    assert!(report.total_profile_iterations < report.exhaustive_equivalent_iterations());

    let found = GraphiEngine::new(report.best.0, report.best.1)
        .with_dispatch(report.best_dispatch)
        .run(&g, &env)
        .makespan_us;
    let sweep = GraphiEngine::new(exhaustive.best.0, exhaustive.best.1).run(&g, &env).makespan_us;
    assert!(
        found <= sweep * 1.05,
        "search best {:?}/{} ({found} µs) not within 5% of exhaustive best {:?} ({sweep} µs)",
        report.best,
        report.best_dispatch.name(),
        exhaustive.best
    );
}

#[test]
fn noisy_search_stays_close_to_the_true_optimum() {
    // with simulated profiling noise (σ = 4 %) the halving may keep a
    // different config than the true argmin, but its noise-free makespan
    // must stay competitive with the noise-free optimum over the whole
    // candidate space
    let g = models::build(ModelKind::PathNet, ModelSize::Small);
    let report = tuner().search(&g, &SimEnv::knl(42));
    let det = SimEnv::knl_deterministic();
    let found = GraphiEngine::new(report.best.0, report.best.1)
        .with_dispatch(report.best_dispatch)
        .run(&g, &det)
        .makespan_us;
    let optimum = tuner()
        .candidate_space()
        .into_iter()
        .map(|((e, t), d)| GraphiEngine::new(e, t).with_dispatch(d).run(&g, &det).makespan_us)
        .fold(f64::INFINITY, f64::min);
    assert!(
        found <= optimum * 1.15,
        "noisy search best {:?} ({found} µs) far off the true optimum ({optimum} µs)",
        report.best
    );
}

#[test]
fn second_invocation_loads_the_artifact_without_searching() {
    let g = models::build(ModelKind::Mlp, ModelSize::Small);
    let env = SimEnv::knl_deterministic();
    let dir = tmpdir("roundtrip");
    let path = tuning_path(&dir, "mlp-small");

    let (first, outcome1) = autotune_or_load(&path, "mlp-small", &tuner(), &g, &env);
    assert_eq!(outcome1, TuneOutcome::FreshSearch);
    assert!(path.is_file(), "artifact not persisted at {}", path.display());

    let (second, outcome2) = autotune_or_load(&path, "mlp-small", &tuner(), &g, &env);
    assert_eq!(outcome2, TuneOutcome::LoadedFromDisk, "second run must not re-search");
    // identical winning config and duration table (JSON round-trip is exact)
    assert_eq!(second.best, first.best);
    assert_eq!(second.durations_us, first.durations_us);
    assert_eq!(second, first);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupt_stale_or_missing_artifacts_degrade_to_fresh_search() {
    let g = models::build(ModelKind::Mlp, ModelSize::Small);
    let env = SimEnv::knl_deterministic();
    let dir = tmpdir("degrade");
    let path = tuning_path(&dir, "mlp-small");

    // missing: plain load errors (no panic), autotune_or_load searches
    assert!(matches!(TuningArtifact::load(&path).unwrap_err(), ArtifactError::Io(_)));

    // corrupt: garbage bytes on disk
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    std::fs::write(&path, "]]not json[[").unwrap();
    let (artifact, outcome) = autotune_or_load(&path, "mlp-small", &tuner(), &g, &env);
    assert_eq!(outcome, TuneOutcome::FreshSearch);
    assert!(artifact.matches_graph(g.len()));
    // …and the corrupt file was replaced by a valid one
    assert_eq!(TuningArtifact::load(&path).unwrap(), artifact);

    // stale: artifact for a different graph shape
    let other = TuningArtifact { graph_nodes: 1, durations_us: vec![1.0], ..artifact.clone() };
    other.save(&path).unwrap();
    let (_, outcome) = autotune_or_load(&path, "mlp-small", &tuner(), &g, &env);
    assert_eq!(outcome, TuneOutcome::FreshSearch, "stale artifact must trigger a re-search");

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn foreign_machine_key_degrades_to_fresh_search() {
    // one tuning dir, two "machines": an artifact tuned under a different
    // (cores, SNC) key must not be reused — it degrades to a fresh search
    // that re-stamps the file with the local key
    let g = models::build(ModelKind::Mlp, ModelSize::Small);
    let env = SimEnv::knl_deterministic();
    let dir = tmpdir("machine-key");
    let path = tuning_path(&dir, "mlp-small");

    let (first, outcome) = autotune_or_load(&path, "mlp-small", &tuner(), &g, &env);
    assert_eq!(outcome, TuneOutcome::FreshSearch);
    assert_eq!(first.machine, MachineKey::of(&env.cost.machine));

    // forge an artifact from a foreign machine (same graph, other hardware)
    let foreign = TuningArtifact {
        machine: MachineKey { cores: 28, numa_domains: 4 },
        ..first.clone()
    };
    foreign.save(&path).unwrap();
    let (second, outcome) = autotune_or_load(&path, "mlp-small", &tuner(), &g, &env);
    assert_eq!(outcome, TuneOutcome::FreshSearch, "foreign machine key must re-search");
    assert_eq!(second.machine, MachineKey::of(&env.cost.machine));
    // the re-search overwrote the foreign artifact, so a third call loads
    let (_, outcome) = autotune_or_load(&path, "mlp-small", &tuner(), &g, &env);
    assert_eq!(outcome, TuneOutcome::LoadedFromDisk);

    std::fs::remove_dir_all(&dir).unwrap();
}

/// A narrow|wide|narrow graph (chain head, 24-wide band of tiny ops,
/// chain tail) — the shape whose phases genuinely want different dispatch
/// architectures, so the per-phase axis has something to find.
fn phased_shape_graph() -> graphi::graph::Graph {
    use graphi::graph::op::{EwKind, OpKind};
    use graphi::graph::GraphBuilder;
    let mut b = GraphBuilder::new();
    let big = |n| OpKind::Elementwise { n, arity: 1, kind: EwKind::Arith };
    let mut prev = b.add("h0", big(50_000));
    for i in 1..6 {
        let n = b.add(format!("h{i}"), big(50_000));
        b.depend(prev, n);
        prev = n;
    }
    let mut band = vec![prev];
    for layer in 0..12 {
        let mut this = Vec::new();
        for i in 0..24 {
            let n = b.add(
                format!("w{layer}_{i}"),
                OpKind::Elementwise { n: 2_000, arity: 2, kind: EwKind::Arith },
            );
            b.depend(band[i % band.len()], n);
            this.push(n);
        }
        band = this;
    }
    let mut last = b.add_after("t0", big(50_000), &band);
    for i in 1..6 {
        let n = b.add(format!("t{i}"), big(50_000));
        b.depend(last, n);
        last = n;
    }
    b.build().unwrap()
}

#[test]
fn v4_artifact_roundtrips_v2_degrades_and_run_adopts_the_phase_plan() {
    let g = models::build(ModelKind::Mlp, ModelSize::Small);
    let env = SimEnv::knl_deterministic();
    let dir = tmpdir("phase-plan");
    let dir_s = dir.display().to_string();
    let path = tuning_path(&dir, "mlp-small");

    // fresh search persists a v4 file that round-trips exactly
    let (artifact, outcome) = autotune_or_load(&path, "mlp-small", &tuner(), &g, &env);
    assert_eq!(outcome, TuneOutcome::FreshSearch);
    assert_eq!(artifact.version, TUNING_FORMAT_VERSION);
    assert_eq!(TuningArtifact::load(&path).unwrap(), artifact);

    // a v2-stamped file (pre-phase-plan schema) degrades to a fresh search
    let mut v2 = artifact.to_json();
    v2.set("version", 2u64);
    std::fs::write(&path, v2.to_string_pretty()).unwrap();
    assert!(matches!(
        TuningArtifact::load(&path).unwrap_err(),
        ArtifactError::TuningVersion { found: 2, .. }
    ));
    let (_, outcome) = autotune_or_load(&path, "mlp-small", &tuner(), &g, &env);
    assert_eq!(outcome, TuneOutcome::FreshSearch, "v2 artifact must re-search");

    // `graphi run --tuning` adoption: an artifact carrying a phase plan
    // flows into the run config (dispatch via the pinned precedence, plan
    // unless an explicit flag pins a uniform mode) and the driver builds
    // a phased engine from it
    let plan = PhasePlan::uniform(
        1,
        DispatchMode::Decentralized,
        graphi::graph::width_phases(&g, 1).len(),
    );
    let with_plan = TuningArtifact {
        phase_plan: Some(plan.clone()),
        ..TuningArtifact::load(&path).unwrap()
    };
    with_plan.save(&path).unwrap();
    let mut cfg = graphi::coordinator::config::ExperimentConfig {
        model: ModelKind::Mlp,
        size: ModelSize::Small,
        iterations: 1,
        ..Default::default()
    };
    graphi::cli::apply_tuning(&mut cfg, &dir_s, None, true);
    assert_eq!(cfg.phase_plan, Some(plan));
    // this artifact was tuned without the width axis, so even --widths
    // has nothing to adopt
    assert_eq!(cfg.width_plan, None);
    assert_eq!(cfg.dispatch, Some(with_plan.best_dispatch));
    assert_eq!(cfg.executors, Some(with_plan.best.0));
    let result = graphi::coordinator::driver::Driver::run(&cfg);
    assert!(result.engine_name.ends_with("-phased"), "{}", result.engine_name);
    // …while an explicit --dispatch flag drops the plan (uniform pin)
    let mut pinned = graphi::coordinator::config::ExperimentConfig {
        model: ModelKind::Mlp,
        size: ModelSize::Small,
        iterations: 1,
        ..Default::default()
    };
    graphi::cli::apply_tuning(&mut pinned, &dir_s, Some(DispatchMode::Centralized), false);
    assert_eq!(pinned.phase_plan, None);
    assert_eq!(pinned.dispatch, Some(DispatchMode::Centralized));

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn autotuner_searches_the_per_phase_axis_on_a_phased_graph() {
    let g = phased_shape_graph();
    let env = SimEnv::knl_deterministic();
    // a 16-core worker pool keeps every candidate's executor count ≤ 16,
    // below the band's width of 24 — so the winner's phase threshold is
    // guaranteed to split the narrow chain ends from the wide band
    let small_pool = Autotuner { worker_cores: 16, ..Default::default() };
    let report = small_pool.search(&g, &env);
    let phases = graphi::graph::width_phases(&g, report.best.0.max(2));
    assert!(phases.len() >= 2, "narrow|wide|narrow shape must produce multiple phases");
    // the refinement ran: one baseline + one flip per phase, exactly
    assert_eq!(report.phase_refine_iterations, phases.len() + 1);
    // whatever was adopted is persistable and re-loadable
    let dir = tmpdir("phase-axis");
    let path = tuning_path(&dir, "phased-shape");
    let artifact =
        TuningArtifact::from_report("phased-shape", g.len(), &env, &small_pool, &report);
    artifact.save(&path).unwrap();
    let back = TuningArtifact::load(&path).unwrap();
    assert_eq!(back.phase_plan, report.phase_plan);
    if let Some(plan) = &back.phase_plan {
        assert!(plan.matches(&g));
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn dispatch_mode_is_part_of_the_persisted_winner() {
    let g = models::build(ModelKind::Mlp, ModelSize::Small);
    let env = SimEnv::knl_deterministic();
    let dir = tmpdir("dispatch-axis");
    let path = tuning_path(&dir, "mlp-small");
    let (artifact, _) = autotune_or_load(&path, "mlp-small", &tuner(), &g, &env);
    assert!(DispatchMode::ALL.contains(&artifact.best_dispatch));
    // the search trace records which mode each surviving candidate ran under
    let modes: std::collections::BTreeSet<&str> = artifact
        .search_trace
        .iter()
        .flat_map(|r| r.measurements.iter().map(|&(_, _, d, _)| d.name()))
        .collect();
    assert!(
        modes.contains("centralized") && modes.contains("decentralized"),
        "both axes must appear in the trace: {modes:?}"
    );
    let reloaded = TuningArtifact::load(&path).unwrap();
    assert_eq!(reloaded, artifact);
    std::fs::remove_dir_all(&dir).unwrap();
}
