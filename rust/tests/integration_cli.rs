//! CLI integration: exercise the `graphi` subcommands end to end through
//! `cli::main` (same code path as the binary).

fn run(args: &[&str]) -> i32 {
    graphi::cli::main(args.iter().map(|s| s.to_string()).collect())
}

#[test]
fn run_with_explicit_fleet() {
    assert_eq!(
        run(&[
            "run", "--model", "pathnet", "--size", "small", "--engine", "graphi",
            "--executors", "6", "--threads", "10", "--iters", "1",
        ]),
        0
    );
}

#[test]
fn run_each_engine() {
    for engine in ["sequential", "naive", "tensorflow"] {
        assert_eq!(
            run(&[
                "run", "--model", "mlp", "--size", "small", "--engine", engine,
                "--executors", "4", "--threads", "8", "--iters", "1",
            ]),
            0,
            "engine {engine}"
        );
    }
}

#[test]
fn run_from_config_file() {
    let path = std::env::temp_dir().join(format!("graphi-cli-cfg-{}.toml", std::process::id()));
    std::fs::write(
        &path,
        r#"
title = "cli integration"
[model]
name = "mlp"
size = "small"
[engine]
kind = "graphi"
executors = 4
threads_per_executor = 8
[run]
iterations = 1
"#,
    )
    .unwrap();
    assert_eq!(run(&["run", "--config", path.to_str().unwrap()]), 0);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn trace_writes_chrome_json() {
    let out = std::env::temp_dir().join(format!("graphi-cli-trace-{}.json", std::process::id()));
    assert_eq!(
        run(&[
            "trace", "--model", "mlp", "--size", "small", "--executors", "2", "--threads", "8",
            "--out", out.to_str().unwrap(),
        ]),
        0
    );
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(text.contains("traceEvents"));
    std::fs::remove_file(&out).unwrap();
}

#[test]
fn stats_writes_dot() {
    let out = std::env::temp_dir().join(format!("graphi-cli-dot-{}.dot", std::process::id()));
    assert_eq!(
        run(&["stats", "--model", "mlp", "--size", "small", "--dot", out.to_str().unwrap()]),
        0
    );
    let text = std::fs::read_to_string(&out).unwrap();
    assert!(text.starts_with("digraph"));
    std::fs::remove_file(&out).unwrap();
}

#[test]
fn profile_mlp() {
    assert_eq!(run(&["profile", "--model", "mlp", "--size", "small", "--iters", "1"]), 0);
}

#[test]
fn json_result_export() {
    let out = std::env::temp_dir().join(format!("graphi-cli-json-{}.json", std::process::id()));
    assert_eq!(
        run(&[
            "run", "--model", "mlp", "--size", "small", "--executors", "2", "--threads", "4",
            "--iters", "1", "--json", out.to_str().unwrap(),
        ]),
        0
    );
    let doc = graphi::util::json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    assert_eq!(doc.get("model").unwrap().as_str().unwrap(), "mlp");
    std::fs::remove_file(&out).unwrap();
}

#[test]
fn errors_are_nonzero() {
    assert_eq!(run(&["run", "--model", "vgg"]), 1);
    assert_eq!(run(&["bench", "not-a-figure"]), 1);
    assert_eq!(run(&["train", "--artifacts", "/definitely/missing"]), 1);
}
