//! Quickstart: build a model graph, run it under three engines, compare.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! This is the 60-second tour of the public API: model compilers
//! ([`graphi::models`]), engines ([`graphi::engine`]), the profiler, and
//! execution traces.

use graphi::engine::{
    Engine, GraphiEngine, NaiveEngine, Profiler, SequentialEngine, SimEnv, Trace,
};
use graphi::graph::GraphStats;
use graphi::models::{self, ModelKind, ModelSize};

fn main() {
    // 1. Compile a model into a computation graph (Table 1 sizes).
    let graph = models::build(ModelKind::Lstm, ModelSize::Medium);
    let stats = GraphStats::compute(&graph);
    println!("medium LSTM training graph:\n{}", stats.render());

    // 2. The simulated KNL environment (68-core Xeon Phi 7250).
    let env = SimEnv::knl(42);

    // 3. Let the profiler pick the executor configuration (§4.2).
    let profiler = Profiler { iterations: 2, ..Default::default() };
    let report = profiler.profile(&graph, &env);
    println!("{}", Profiler::render(&report));
    let (execs, threads) = report.best;

    // 4. Compare engines at that configuration.
    let sequential = SequentialEngine::new(64).run(&graph, &env);
    let naive = NaiveEngine::new(execs, threads).run(&graph, &env);
    let graphi = GraphiEngine::new(execs, threads).run(&graph, &env);
    println!("sequential (S64):  {}", graphi::util::fmt_us(sequential.makespan_us));
    println!(
        "naive {}x{}:        {}  ({:.2}x vs sequential)",
        execs,
        threads,
        graphi::util::fmt_us(naive.makespan_us),
        sequential.makespan_us / naive.makespan_us
    );
    println!(
        "graphi {}x{}:       {}  ({:.2}x vs sequential, {:.1}% faster than naive)",
        execs,
        threads,
        graphi::util::fmt_us(graphi.makespan_us),
        sequential.makespan_us / graphi.makespan_us,
        100.0 * (1.0 - graphi.makespan_us / naive.makespan_us),
    );

    // 5. Inspect the execution as a timeline.
    let trace = Trace { records: graphi.records.clone() };
    println!("\nexecutor timelines (first 90 cols):");
    print!("{}", trace.render_ascii(&graph, 90));
    println!(
        "depth/start-time correlation: {:.3} (≈1 ⇒ wavefront execution, §7.4)",
        trace.depth_time_correlation(&graph)
    );
}
