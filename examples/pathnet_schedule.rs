//! PathNet scheduling deep-dive: why the optimal fleet is 6 executors.
//!
//! ```bash
//! cargo run --release --example pathnet_schedule
//! ```
//!
//! The paper's §7.3 observes that PathNet (6 parallel modules per layer)
//! peaks at exactly 6 executors. This example sweeps fleet shapes on the
//! medium PathNet, prints the utilization story behind the optimum, and
//! shows how the critical-path-first policy compares with the naive
//! shared-queue baseline at each shape (Table 2's per-config view).

use graphi::engine::{Engine, GraphiEngine, NaiveEngine, SequentialEngine, SimEnv};
use graphi::graph::op::OpClass;
use graphi::graph::stats::max_parallel_of_class;
use graphi::graph::GraphStats;
use graphi::models::{self, ModelKind, ModelSize};
use graphi::util::table::Table;

fn main() {
    let graph = models::build(ModelKind::PathNet, ModelSize::Medium);
    let stats = GraphStats::compute(&graph);
    println!("medium PathNet training graph:\n{}", stats.render());
    println!(
        "parallel conv modules at one depth: {} (the 6 active modules per layer)\n",
        max_parallel_of_class(&graph, OpClass::Conv)
    );

    let env = SimEnv::knl(7);
    let seq = SequentialEngine::new(64).run(&graph, &env).makespan_us;

    let mut table = Table::new(&[
        "fleet", "graphi", "vs S64", "utilization", "naive", "graphi gain",
    ]);
    table.row(&["S64".into(), graphi::util::fmt_us(seq), "1.00".into(), "100%".into(), "-".into(), "-".into()]);
    let mut best = (String::new(), f64::INFINITY);
    for (e, t) in [(2usize, 32usize), (3, 21), (4, 16), (6, 10), (8, 8), (16, 4), (32, 2)] {
        let g = GraphiEngine::new(e, t).run(&graph, &env);
        let n = NaiveEngine::new(e, t).run(&graph, &env);
        let fleet = format!("{e}x{t}");
        if g.makespan_us < best.1 {
            best = (fleet.clone(), g.makespan_us);
        }
        table.row(&[
            fleet,
            graphi::util::fmt_us(g.makespan_us),
            format!("{:.2}", g.makespan_us / seq),
            format!("{:.0}%", 100.0 * g.metrics.utilization(g.makespan_us)),
            graphi::util::fmt_us(n.makespan_us),
            format!("{:.1}%", 100.0 * (1.0 - g.makespan_us / n.makespan_us)),
        ]);
    }
    print!("{}", table.render());
    println!(
        "\nbest fleet: {} — the module count sets the useful executor count (§7.3)",
        best.0
    );
}
