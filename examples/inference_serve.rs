//! Batched-inference serving study on forward-only graphs (§2: "one
//! complete execution of the graph typically results in the inference of a
//! group of instances").
//!
//! ```bash
//! cargo run --release --example inference_serve
//! ```
//!
//! Streams a queue of inference batches through each engine and reports
//! per-batch latency (p50/p99) and throughput (instances/s). Inference
//! graphs are forward-only — about 40 % of the training node count with
//! *less* intrinsic parallelism (no dgrad/wgrad fan-out), so the optimal
//! fleet is smaller than for training: exactly the kind of question the
//! profiler answers per-deployment.

use graphi::engine::{Engine, GraphiEngine, SequentialEngine, SimEnv};
use graphi::graph::GraphStats;
use graphi::models::{self, config::batch_size, ModelKind, ModelSize};
use graphi::util::stats::Summary;
use graphi::util::table::Table;

fn main() {
    let requests = 40; // batches in the arrival queue
    println!("serving {requests} inference batches per model (medium size)\n");
    let mut table = Table::new(&[
        "model", "nodes", "engine", "batch p50", "batch p99", "instances/s",
    ]);
    for kind in [ModelKind::Lstm, ModelKind::PathNet, ModelKind::GoogleNet] {
        let graph = models::build_inference(kind, ModelSize::Medium);
        let stats = GraphStats::compute(&graph);
        let batch = batch_size(kind) as f64;
        let engines: Vec<(String, Box<dyn Engine>)> = vec![
            ("sequential".into(), Box::new(SequentialEngine::new(64))),
            ("graphi 2x32".into(), Box::new(GraphiEngine::new(2, 32))),
            ("graphi 4x16".into(), Box::new(GraphiEngine::new(4, 16))),
            ("graphi 8x8".into(), Box::new(GraphiEngine::new(8, 8))),
        ];
        for (label, engine) in engines {
            let mut latencies = Vec::with_capacity(requests);
            let mut total_us = 0.0;
            for r in 0..requests {
                let env = SimEnv::knl(0x5E4E ^ (r as u64) << 8 ^ kind as u64);
                let result = engine.run(&graph, &env);
                latencies.push(result.makespan_us);
                total_us += result.makespan_us;
            }
            let s = Summary::from_samples(&latencies);
            table.row(&[
                kind.name().to_string(),
                stats.nodes.to_string(),
                label,
                graphi::util::fmt_us(s.p50),
                graphi::util::fmt_us(s.p99),
                format!("{:.0}", batch * requests as f64 / (total_us * 1e-6)),
            ]);
        }
    }
    print!("{}", table.render());
    println!(
        "\ninference graphs are narrower than training graphs (no dgrad/wgrad\n\
         fan-out), so the best fleet is smaller — rerun `graphi profile` per\n\
         deployment, as §4.2 prescribes."
    );
}
