//! Trace explorer: §7.4's observation that critical-path-first scheduling
//! automatically recovers cuDNN's hand-tuned diagonal wavefront on LSTM.
//!
//! ```bash
//! cargo run --release --example trace_explorer
//! ```
//!
//! Runs the medium LSTM under (a) Graphi's CP-first scheduler and (b) the
//! anti-critical adversary, then compares when each LSTM cell's fused GEMM
//! starts: under CP-first, cell (t, ℓ) start times advance with the
//! anti-diagonal t + ℓ — the cuDNN pattern — while the adversarial order
//! scrambles it. Chrome traces for both land in reports/.

use graphi::engine::{Engine, GraphiEngine, Policy, SimEnv, Trace};
use graphi::models::lstm::{build as build_lstm, LstmConfig};
use graphi::models::ModelSize;

/// Pearson correlation of (t + ℓ) against the cell GEMM start time.
fn wavefront_correlation(
    graph: &graphi::graph::Graph,
    records: &[graphi::engine::OpRecord],
) -> f64 {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for r in records {
        let name = &graph.node(r.node).name;
        // forward cell GEMMs are named "t{t}.l{l}.gemm"
        if let Some(rest) = name.strip_prefix('t') {
            if let Some((t_part, tail)) = rest.split_once(".l") {
                if let Some((l_part, op)) = tail.split_once('.') {
                    if op == "gemm" {
                        if let (Ok(t), Ok(l)) = (t_part.parse::<f64>(), l_part.parse::<f64>()) {
                            xs.push(t + l);
                            ys.push(r.start_us);
                        }
                    }
                }
            }
        }
    }
    assert!(!xs.is_empty(), "no cell GEMMs found in trace");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let (mut cov, mut vx, mut vy) = (0.0, 0.0, 0.0);
    for (x, y) in xs.iter().zip(&ys) {
        cov += (x - mx) * (y - my);
        vx += (x - mx) * (x - mx);
        vy += (y - my) * (y - my);
    }
    cov / (vx.sqrt() * vy.sqrt())
}

fn main() -> graphi::util::error::Result<()> {
    let graph = build_lstm(&LstmConfig::for_size(ModelSize::Medium, false));
    let env = SimEnv::knl(11);
    std::fs::create_dir_all("reports")?;

    println!("medium LSTM, 8x8 fleet — comparing scheduling policies\n");
    let mut rows = Vec::new();
    for policy in [Policy::CriticalPathFirst, Policy::Fifo, Policy::Random, Policy::AntiCritical] {
        let engine = GraphiEngine::new(8, 8).with_policy(policy);
        let result = engine.run(&graph, &env);
        let trace = Trace { records: result.records.clone() };
        let wf = wavefront_correlation(&graph, &result.records);
        let path = format!("reports/trace_{}.json", policy.name());
        std::fs::write(&path, trace.to_chrome_json(&graph))?;
        rows.push((policy.name(), result.makespan_us, wf, path));
    }
    let mut t = graphi::util::table::Table::new(&["policy", "makespan", "wavefront corr", "trace"]);
    for (name, us, wf, path) in &rows {
        t.row(&[
            name.to_string(),
            graphi::util::fmt_us(*us),
            format!("{wf:.3}"),
            path.clone(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nCP-first's wavefront correlation ({:.3}) ≈ the hand-tuned cuDNN diagonal (§7.4);\n\
         open the traces in ui.perfetto.dev to see the executor timelines.",
        rows[0].2
    );
    Ok(())
}
