//! End-to-end driver: real LSTM language-model training through the full
//! three-layer stack.
//!
//! ```bash
//! make artifacts                              # Python runs ONCE
//! cargo run --release --example lstm_train    # pure Rust from here on
//! ```
//!
//! Layer 1 (Pallas fused LSTM cell) and Layer 2 (JAX forward/backward/SGD)
//! were AOT-lowered to `artifacts/train_step.hlo.txt`; this example loads
//! it through the PJRT CPU client (Layer 3) and trains a ~1.2M-parameter
//! byte-level LM on a synthetic corpus for a few hundred steps, logging
//! the loss curve. The recorded reference run lives in EXPERIMENTS.md §E2E.
//!
//! Environment: `GRAPHI_ARTIFACTS` overrides the artifact directory;
//! `STEPS` overrides the step count (default 300).

use graphi::runtime::{ArtifactSet, LstmTrainer, PjrtRuntime};
use graphi::util::error::Result;

fn main() -> Result<()> {
    let steps: usize = std::env::var("STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
    let dir = graphi::runtime::artifacts::default_dir();
    println!("loading artifacts from {} …", dir.display());
    let set = ArtifactSet::load(&dir)?;
    for m in &set.modules {
        println!("  module {:12} inputs {:?} outputs {:?}", m.name, m.inputs, m.outputs);
    }

    let runtime = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", runtime.platform());

    let mut trainer = LstmTrainer::new(&runtime, &set, 42)?;
    println!("parameters: {}", trainer.param_count());
    let (execs, threads) = trainer.parallelism();
    println!("parallel setting: {execs}x{threads} (tuning artifact when present, else S64 default)");
    println!("training byte-LM for {steps} steps on the synthetic corpus …\n");

    let report = trainer.train(steps, 0xC0DE, steps / 20)?;

    println!("\nloss curve:");
    print!("{}", report.render_curve(20));
    println!(
        "\n{} steps in {:.1}s — {:.2} steps/s",
        report.steps, report.wall_s, report.steps_per_s
    );
    println!(
        "initial loss {:.4} (≈ln 256 = 5.545 for uniform bytes) → final loss {:.4}",
        report.initial_loss(),
        report.final_loss()
    );
    graphi::ensure!(
        report.final_loss() < report.initial_loss() - 0.5,
        "training failed to reduce loss meaningfully"
    );
    println!("✓ loss decreased through the full rust→PJRT→(JAX+Pallas AOT) stack");

    // persist the curve for EXPERIMENTS.md
    let mut csv = String::from("step,loss\n");
    for (i, l) in report.losses.iter().enumerate() {
        csv.push_str(&format!("{i},{l}\n"));
    }
    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/lstm_train_loss.csv", csv)?;
    println!("curve written to reports/lstm_train_loss.csv");
    Ok(())
}
