"""Layer-2 model tests: shapes, learning dynamics, Pallas/ref agreement."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    ModelConfig,
    forward_loss,
    forward_loss_jit,
    init_params,
    param_count,
    param_shapes,
    train_step_jit,
    unflatten,
)

TINY = ModelConfig(hidden=32, layers=2, seq=6, batch=4)


def _tokens(cfg, seed=0):
    return jax.random.randint(
        jax.random.PRNGKey(seed), (cfg.batch, cfg.seq + 1), 0, cfg.vocab
    ).astype(jnp.float32)


def test_param_packing_roundtrip():
    cfg = TINY
    flat = init_params(cfg, jax.random.PRNGKey(1))
    assert flat.shape == (param_count(cfg),)
    params = unflatten(cfg, flat)
    assert set(params) == set(param_shapes(cfg))
    # repack in order and compare
    repacked = jnp.concatenate([params[k].reshape(-1) for k in param_shapes(cfg)])
    np.testing.assert_array_equal(flat, repacked)


def test_initial_loss_near_uniform_entropy():
    """Random init ⇒ loss ≈ ln(vocab)."""
    cfg = TINY
    flat = init_params(cfg, jax.random.PRNGKey(2))
    loss = forward_loss(cfg, flat, _tokens(cfg))
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5, float(loss)


def test_training_reduces_loss():
    cfg = TINY
    flat = init_params(cfg, jax.random.PRNGKey(3))
    toks = _tokens(cfg, seed=7)
    loss0, flat = train_step_jit(cfg, flat, toks)
    loss = loss0
    for _ in range(15):
        loss, flat = train_step_jit(cfg, flat, toks)
    assert float(loss[0]) < float(loss0[0]) - 0.1, (float(loss0[0]), float(loss[0]))


def test_pallas_and_ref_models_agree():
    """The whole model must be bitwise-insensitive to the kernel choice."""
    cfg_pallas = TINY
    cfg_ref = dataclasses.replace(TINY, use_pallas=False)
    flat = init_params(cfg_pallas, jax.random.PRNGKey(4))
    toks = _tokens(cfg_pallas, seed=9)
    loss_p = forward_loss(cfg_pallas, flat, toks)
    loss_r = forward_loss(cfg_ref, flat, toks)
    np.testing.assert_allclose(loss_p, loss_r, rtol=1e-6, atol=1e-6)
    # gradients too
    gp = jax.grad(lambda f: forward_loss(cfg_pallas, f, toks))(flat)
    gr = jax.grad(lambda f: forward_loss(cfg_ref, f, toks))(flat)
    np.testing.assert_allclose(gp, gr, rtol=1e-4, atol=1e-6)


def test_forward_loss_jit_returns_tuple():
    cfg = TINY
    flat = init_params(cfg, jax.random.PRNGKey(5))
    out = forward_loss_jit(cfg, flat, _tokens(cfg))
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (1,)


def test_train_step_shapes():
    cfg = TINY
    flat = init_params(cfg, jax.random.PRNGKey(6))
    loss, new = train_step_jit(cfg, flat, _tokens(cfg))
    assert loss.shape == (1,)
    assert new.shape == flat.shape
    assert not np.array_equal(np.asarray(new), np.asarray(flat)), "params must move"


def test_deterministic_given_seed():
    cfg = TINY
    a = init_params(cfg, jax.random.PRNGKey(8))
    b = init_params(cfg, jax.random.PRNGKey(8))
    np.testing.assert_array_equal(a, b)
