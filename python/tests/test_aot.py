"""AOT export tests: lowering, manifest integrity, HLO-text format."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import lower_modules, to_hlo_text, write_artifacts
from compile.model import ModelConfig, param_count

TINY = ModelConfig(hidden=16, layers=1, seq=4, batch=2)


@pytest.fixture(scope="module")
def modules():
    return lower_modules(TINY)


def test_all_three_modules_lowered(modules):
    assert set(modules) == {"train_step", "forward_loss", "lstm_cell", "phased_gate"}


def test_hlo_is_text_not_proto(modules):
    for name, (hlo, _, _, _) in modules.items():
        assert hlo.startswith("HloModule"), f"{name} must be HLO text"
        # the 0.5.1-incompatible path would be binary; text is ASCII
        assert hlo.isascii()


def test_train_step_shapes_recorded(modules):
    hlo, inputs, outputs, meta = modules["train_step"]
    p = param_count(TINY)
    assert inputs == [[p], [TINY.batch, TINY.seq + 1]]
    assert outputs == [[1], [p]]
    assert meta["param_count"] == p
    assert meta["hidden"] == TINY.hidden


def test_no_mosaic_custom_calls(modules):
    """interpret=True must lower the Pallas kernel to plain HLO — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    for name, (hlo, _, _, _) in modules.items():
        assert "mosaic" not in hlo.lower(), f"{name} contains a Mosaic custom-call"


def test_write_artifacts_and_manifest(tmp_path):
    write_artifacts(str(tmp_path), TINY)
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert len(manifest["modules"]) == 4
    for m in manifest["modules"]:
        assert os.path.isfile(tmp_path / m["file"])
        assert m["inputs"] and m["outputs"]


def test_lowered_train_step_runs_in_jax(modules):
    """Round-trip sanity: execute the same jitted fn that was lowered."""
    from compile.model import init_params, train_step_jit

    flat = init_params(TINY, jax.random.PRNGKey(0))
    toks = jax.random.randint(
        jax.random.PRNGKey(1), (TINY.batch, TINY.seq + 1), 0, 256
    ).astype(jnp.float32)
    loss, new = train_step_jit(TINY, flat, toks)
    assert np.isfinite(float(loss[0]))
    assert new.shape == flat.shape


def test_to_hlo_text_on_simple_fn():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(jax.ShapeDtypeStruct((2,), jnp.float32))
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "multiply" in text
