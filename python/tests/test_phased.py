"""PhasedLSTM time-gate kernel vs its oracle."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.phased_gate import phased_gate, phased_gate_ref

hypothesis.settings.register_profile(
    "ci", max_examples=20, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("ci")


def _case(batch, hidden, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    mk = lambda k: jax.random.normal(k, (batch, hidden), jnp.float32)
    c_cand, h_cand, c_prev, h_prev = mk(keys[0]), mk(keys[1]), mk(keys[2]), mk(keys[3])
    tau = jax.random.uniform(keys[4], (hidden,), jnp.float32, 1.0, 100.0)
    shift = jax.random.uniform(keys[5], (hidden,), jnp.float32, 0.0, 10.0)
    return c_cand, h_cand, c_prev, h_prev, tau, shift


@hypothesis.given(
    batch=st.integers(min_value=1, max_value=8),
    hidden_pow=st.integers(min_value=3, max_value=8),
    t=st.floats(min_value=0.0, max_value=1000.0, allow_nan=False),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matches_ref(batch, hidden_pow, t, seed):
    hidden = 1 << hidden_pow
    args = _case(batch, hidden, seed)
    time = jnp.asarray(t, jnp.float32)
    ck, hk = phased_gate(*args, time)
    cr, hr = phased_gate_ref(*args, time)
    np.testing.assert_allclose(ck, cr, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(hk, hr, rtol=1e-5, atol=1e-5)


def test_closed_gate_preserves_state():
    """Deep in the closed phase (phi ≈ 0.5, leak tiny) the state barely
    moves: c ≈ c_prev."""
    batch, hidden = 4, 32
    c_cand = jnp.full((batch, hidden), 10.0)
    h_cand = jnp.full((batch, hidden), -10.0)
    c_prev = jnp.ones((batch, hidden))
    h_prev = jnp.zeros((batch, hidden))
    tau = jnp.full((hidden,), 2.0)
    shift = jnp.zeros((hidden,))
    time = jnp.asarray(1.0, jnp.float32)  # phi = 0.5, far past r_on=0.05
    c, h = phased_gate(c_cand, h_cand, c_prev, h_prev, tau, shift, time)
    np.testing.assert_allclose(c, c_prev + 0.0005 * (10.0 - 1.0), rtol=1e-3)
    assert float(jnp.abs(h).max()) < 0.01


def test_open_gate_passes_candidate():
    """At phi = r_on/2 the gate is fully open: state = candidate."""
    batch, hidden = 2, 16
    c_cand = jnp.full((batch, hidden), 3.0)
    h_cand = jnp.full((batch, hidden), -2.0)
    c_prev = jnp.zeros((batch, hidden))
    h_prev = jnp.zeros((batch, hidden))
    r_on = 0.05
    tau = jnp.full((hidden,), 100.0)
    shift = jnp.zeros((hidden,))
    time = jnp.asarray(100.0 * r_on / 2.0, jnp.float32)  # phi = r_on/2
    c, h = phased_gate(c_cand, h_cand, c_prev, h_prev, tau, shift, time, r_on=r_on)
    np.testing.assert_allclose(c, c_cand, rtol=1e-5)
    np.testing.assert_allclose(h, h_cand, rtol=1e-5)


def test_gate_is_periodic():
    args = _case(3, 64, 7)
    tau = args[4]
    a = phased_gate(*args, jnp.asarray(5.0, jnp.float32))
    b = phased_gate(*args[:4], tau, args[5], jnp.asarray(5.0, jnp.float32))
    np.testing.assert_allclose(a[0], b[0], rtol=1e-6)
    # shifting time by exactly tau (per-unit) reproduces the same gate —
    # check with a uniform tau
    uniform_tau = jnp.full_like(tau, 10.0)
    x = phased_gate(*args[:4], uniform_tau, args[5], jnp.asarray(3.0, jnp.float32))
    y = phased_gate(*args[:4], uniform_tau, args[5], jnp.asarray(13.0, jnp.float32))
    np.testing.assert_allclose(x[0], y[0], rtol=1e-4, atol=1e-5)
