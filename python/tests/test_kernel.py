"""Layer-1 kernel correctness: Pallas LSTM cell vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes and block sizes; every case asserts
forward and backward numerics against ``ref.py``.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.lstm_cell import lstm_cell, vmem_bytes
from compile.kernels.ref import lstm_cell_ref

hypothesis.settings.register_profile(
    "ci", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("ci")


def _random_case(batch, hidden, seed, dtype=jnp.float32):
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    gates = jax.random.normal(k0, (batch, 4 * hidden), dtype) * 2.0
    c_prev = jax.random.normal(k1, (batch, hidden), dtype)
    return gates, c_prev


@hypothesis.given(
    batch=st.integers(min_value=1, max_value=16),
    hidden_pow=st.integers(min_value=3, max_value=9),  # 8..512
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_forward_matches_ref(batch, hidden_pow, seed):
    hidden = 1 << hidden_pow
    gates, c_prev = _random_case(batch, hidden, seed)
    h_k, c_k = lstm_cell(gates, c_prev)
    h_r, c_r = lstm_cell_ref(gates, c_prev)
    np.testing.assert_allclose(h_k, h_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(c_k, c_r, rtol=1e-5, atol=1e-5)


@hypothesis.given(
    batch=st.integers(min_value=1, max_value=8),
    hidden_pow=st.integers(min_value=3, max_value=8),
    block_pow=st.integers(min_value=2, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_block_size_invariance(batch, hidden_pow, block_pow, seed):
    """The tile width is a performance knob — results must not change."""
    hidden = 1 << hidden_pow
    block_h = min(1 << block_pow, hidden)
    gates, c_prev = _random_case(batch, hidden, seed)
    h_a, c_a = lstm_cell(gates, c_prev, block_h=block_h)
    h_b, c_b = lstm_cell(gates, c_prev, block_h=hidden)
    np.testing.assert_allclose(h_a, h_b, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(c_a, c_b, rtol=1e-6, atol=1e-6)


@hypothesis.given(
    batch=st.integers(min_value=1, max_value=8),
    hidden_pow=st.integers(min_value=3, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_backward_matches_ref(batch, hidden_pow, seed):
    """The fused VJP kernel must agree with autodiff through the oracle."""
    hidden = 1 << hidden_pow
    gates, c_prev = _random_case(batch, hidden, seed)

    def loss_kernel(g, c):
        h, cn = lstm_cell(g, c)
        return jnp.sum(jnp.sin(h) + 0.5 * cn)

    def loss_ref(g, c):
        h, cn = lstm_cell_ref(g, c)
        return jnp.sum(jnp.sin(h) + 0.5 * cn)

    gk = jax.grad(loss_kernel, argnums=(0, 1))(gates, c_prev)
    gr = jax.grad(loss_ref, argnums=(0, 1))(gates, c_prev)
    np.testing.assert_allclose(gk[0], gr[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gk[1], gr[1], rtol=1e-4, atol=1e-5)


def test_bfloat16_supported():
    gates, c_prev = _random_case(4, 64, 0, dtype=jnp.bfloat16)
    h_k, c_k = lstm_cell(gates, c_prev)
    h_r, c_r = lstm_cell_ref(gates, c_prev)
    assert h_k.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        h_k.astype(np.float32), h_r.astype(np.float32), rtol=2e-2, atol=2e-2
    )
    np.testing.assert_allclose(
        c_k.astype(np.float32), c_r.astype(np.float32), rtol=2e-2, atol=2e-2
    )


def test_extreme_inputs_stay_finite():
    """Saturated gates must not produce NaN/Inf (sigmoid/tanh plateaus)."""
    gates = jnp.full((2, 4 * 32), 50.0, jnp.float32)
    c_prev = jnp.full((2, 32), -30.0, jnp.float32)
    h, c = lstm_cell(gates, c_prev)
    assert np.isfinite(np.asarray(h)).all()
    assert np.isfinite(np.asarray(c)).all()
    # f≈1, i≈1, g≈1 → c ≈ c_prev + 1
    np.testing.assert_allclose(c, c_prev + 1.0, rtol=1e-5)


def test_zero_gates_identity_ish():
    """At zero pre-activations: c = σ(1)·c_prev + 0.5·tanh(0) = σ(1)·c_prev."""
    gates = jnp.zeros((3, 4 * 16), jnp.float32)
    c_prev = jnp.ones((3, 16), jnp.float32)
    _, c = lstm_cell(gates, c_prev)
    sig1 = 1.0 / (1.0 + np.exp(-1.0))
    np.testing.assert_allclose(c, np.full((3, 16), sig1), rtol=1e-6)


def test_bad_block_size_rejected():
    gates, c_prev = _random_case(2, 24, 0)
    with pytest.raises(AssertionError):
        lstm_cell(gates, c_prev, block_h=16)  # 24 % 16 != 0


def test_vmem_estimate_within_budget():
    """DESIGN.md §Perf: default tile must fit VMEM with large margin."""
    assert vmem_bytes(batch=64, block_h=128) < 16 * 1024 * 1024
