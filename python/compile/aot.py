"""AOT lowering: JAX → HLO text artifacts + manifest.

Runs ONCE at build time (`make artifacts`). Produces:

    artifacts/train_step.hlo.txt    (loss[1], new_params[P]) ← (params, tokens)
    artifacts/forward_loss.hlo.txt  (loss[1],)               ← (params, tokens)
    artifacts/lstm_cell.hlo.txt     (h, c)                   ← (gates, c_prev)
    artifacts/manifest.json         shapes + hyper-parameters for Rust

HLO *text* is the interchange format (NOT ``lowered.compiler_ir("hlo")`` or
serialized protos): jax ≥ 0.5 emits protos with 64-bit instruction ids that
xla_extension 0.5.1 rejects; the text parser reassigns ids. See
/opt/xla-example/README.md and gen_hlo.py.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelConfig, forward_loss_jit, param_count, train_step_jit
from .kernels.lstm_cell import lstm_cell
from .kernels.phased_gate import phased_gate


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_modules(cfg: ModelConfig):
    """Lower all modules; returns {name: (hlo_text, inputs, outputs, meta)}."""
    p = param_count(cfg)
    params_spec = jax.ShapeDtypeStruct((p,), jnp.float32)
    tokens_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq + 1), jnp.float32)

    meta = {
        "vocab": cfg.vocab,
        "hidden": cfg.hidden,
        "layers": cfg.layers,
        "seq": cfg.seq,
        "batch": cfg.batch,
        "lr": cfg.lr,
        "init_scale": cfg.init_scale,
        "param_count": p,
    }

    modules = {}
    lowered = train_step_jit.lower(cfg, params_spec, tokens_spec)
    modules["train_step"] = (
        to_hlo_text(lowered),
        [[p], [cfg.batch, cfg.seq + 1]],
        [[1], [p]],
        meta,
    )
    lowered = forward_loss_jit.lower(cfg, params_spec, tokens_spec)
    modules["forward_loss"] = (
        to_hlo_text(lowered),
        [[p], [cfg.batch, cfg.seq + 1]],
        [[1]],
        meta,
    )
    # the Layer-1 kernel standalone, for kernel-level integration tests
    gates_spec = jax.ShapeDtypeStruct((cfg.batch, 4 * cfg.hidden), jnp.float32)
    c_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.hidden), jnp.float32)
    lowered = jax.jit(lambda g, c: lstm_cell(g, c, block_h=min(128, cfg.hidden))).lower(
        gates_spec, c_spec
    )
    modules["lstm_cell"] = (
        to_hlo_text(lowered),
        [[cfg.batch, 4 * cfg.hidden], [cfg.batch, cfg.hidden]],
        [[cfg.batch, cfg.hidden], [cfg.batch, cfg.hidden]],
        {"hidden": cfg.hidden, "batch": cfg.batch},
    )
    # the PhasedLSTM time gate, standalone
    bh_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.hidden), jnp.float32)
    h_spec = jax.ShapeDtypeStruct((cfg.hidden,), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(
        lambda cc, hc, cp, hp, tau, shift, t: phased_gate(
            cc, hc, cp, hp, tau, shift, t, block_h=min(128, cfg.hidden)
        )
    ).lower(bh_spec, bh_spec, bh_spec, bh_spec, h_spec, h_spec, t_spec)
    bh = [cfg.batch, cfg.hidden]
    modules["phased_gate"] = (
        to_hlo_text(lowered),
        [bh, bh, bh, bh, [cfg.hidden], [cfg.hidden], []],
        [bh, bh],
        {"hidden": cfg.hidden, "batch": cfg.batch},
    )
    return modules


def write_artifacts(out_dir: str, cfg: ModelConfig) -> None:
    os.makedirs(out_dir, exist_ok=True)
    modules = lower_modules(cfg)
    manifest = {"modules": []}
    for name, (hlo, inputs, outputs, meta) in modules.items():
        file_name = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, file_name), "w") as f:
            f.write(hlo)
        manifest["modules"].append(
            {"name": name, "file": file_name, "inputs": inputs, "outputs": outputs, "meta": meta}
        )
        print(f"wrote {file_name} ({len(hlo)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['modules'])} modules)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.5)
    args = ap.parse_args()
    cfg = ModelConfig(
        hidden=args.hidden, layers=args.layers, seq=args.seq, batch=args.batch, lr=args.lr
    )
    print(f"lowering byte-LM: {param_count(cfg)} params, cfg={cfg}")
    write_artifacts(args.out, cfg)


if __name__ == "__main__":
    main()
