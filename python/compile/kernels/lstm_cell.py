"""Layer-1 Pallas kernels: fused LSTM cell update, forward and backward.

The paper's workloads are element-wise-dense LSTMs on a manycore CPU; the
analogous TPU hot-spot is the cell's fused gate math (DESIGN.md
§Hardware-Adaptation). One forward invocation reads the `[B, 4H]` gate
pre-activations and `[B, H]` previous cell state from HBM once, computes
all five transcendental gate ops fused in VMEM, and writes only `(h, c)` —
the write-once/no-readback structure that mirrors the paper's stream-store
optimization (§6). The backward pass is a second fused kernel (Pallas
interpret mode has no reverse-mode AD, and a fused VJP is what a production
kernel ships anyway), wired in via ``jax.custom_vjp``.

Tiling: the grid walks `H` in `block_h` columns (each block owns the four
gate slices for its columns), so VMEM residency per forward step is
`B·block_h·9·4` bytes — comfortably under the ~16 MB VMEM budget at the
defaults. `B` rides along whole because the evaluation batch (≤64) is
small; a production kernel on huge batches would tile `B` the same way.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernels lower to plain HLO. Real-TPU perf is
estimated from the VMEM/MXU structure in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import FORGET_BIAS

DEFAULT_BLOCK_H = 128


def _split_gates(gates_ref, block_h):
    i = gates_ref[:, 0 * block_h : 1 * block_h]
    f = gates_ref[:, 1 * block_h : 2 * block_h]
    g = gates_ref[:, 2 * block_h : 3 * block_h]
    o = gates_ref[:, 3 * block_h : 4 * block_h]
    return i, f, g, o


def _fwd_kernel(gates_ref, c_prev_ref, h_ref, c_ref):
    """One grid step: full batch × `block_h` hidden columns."""
    block_h = c_ref.shape[-1]
    i, f, g, o = _split_gates(gates_ref, block_h)
    c_prev = c_prev_ref[...]
    c_new = jax.nn.sigmoid(f + FORGET_BIAS) * c_prev + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_ref[...] = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    c_ref[...] = c_new


def _bwd_kernel(gates_ref, c_prev_ref, dh_ref, dc_in_ref, dgates_ref, dc_prev_ref):
    """Fused VJP: recompute activations in VMEM, emit dgates and dc_prev."""
    block_h = c_prev_ref.shape[-1]
    i, f, g, o = _split_gates(gates_ref, block_h)
    c_prev = c_prev_ref[...]
    si = jax.nn.sigmoid(i)
    sf = jax.nn.sigmoid(f + FORGET_BIAS)
    sg = jnp.tanh(g)
    so = jax.nn.sigmoid(o)
    c_new = sf * c_prev + si * sg
    tc = jnp.tanh(c_new)
    dh = dh_ref[...]
    dc = dc_in_ref[...] + dh * so * (1.0 - tc * tc)
    d_i = dc * sg * si * (1.0 - si)
    d_f = dc * c_prev * sf * (1.0 - sf)
    d_g = dc * si * (1.0 - sg * sg)
    d_o = dh * tc * so * (1.0 - so)
    dgates_ref[:, 0 * block_h : 1 * block_h] = d_i
    dgates_ref[:, 1 * block_h : 2 * block_h] = d_f
    dgates_ref[:, 2 * block_h : 3 * block_h] = d_g
    dgates_ref[:, 3 * block_h : 4 * block_h] = d_o
    dc_prev_ref[...] = dc * sf


def _tile_gates(gates: jnp.ndarray, hidden: int, block_h: int) -> jnp.ndarray:
    """[B, 4H] → tile-major layout where the four gate slices for each
    `block_h` column tile are adjacent (one rectangular block per grid
    step)."""
    batch = gates.shape[0]
    g4 = gates.reshape(batch, 4, hidden // block_h, block_h)
    return jnp.swapaxes(g4, 1, 2).reshape(batch, 4 * hidden)


def _untile_gates(tiled: jnp.ndarray, hidden: int, block_h: int) -> jnp.ndarray:
    """Inverse of :func:`_tile_gates`."""
    batch = tiled.shape[0]
    g4 = tiled.reshape(batch, hidden // block_h, 4, block_h)
    return jnp.swapaxes(g4, 1, 2).reshape(batch, 4 * hidden)


def _specs(batch, block_h, n_gates):
    def index(j):
        return (0, j)

    return pl.BlockSpec((batch, n_gates * block_h), index)


def _cell_fwd_pallas(gates, c_prev, block_h):
    batch, hidden = c_prev.shape
    grid = (hidden // block_h,)
    tiled = _tile_gates(gates, hidden, block_h)
    h, c = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[_specs(batch, block_h, 4), _specs(batch, block_h, 1)],
        out_specs=[_specs(batch, block_h, 1), _specs(batch, block_h, 1)],
        out_shape=[
            jax.ShapeDtypeStruct((batch, hidden), c_prev.dtype),
            jax.ShapeDtypeStruct((batch, hidden), c_prev.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(tiled, c_prev)
    return h, c


def _cell_bwd_pallas(gates, c_prev, dh, dc_in, block_h):
    batch, hidden = c_prev.shape
    grid = (hidden // block_h,)
    tiled = _tile_gates(gates, hidden, block_h)
    dgates_tiled, dc_prev = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            _specs(batch, block_h, 4),
            _specs(batch, block_h, 1),
            _specs(batch, block_h, 1),
            _specs(batch, block_h, 1),
        ],
        out_specs=[_specs(batch, block_h, 4), _specs(batch, block_h, 1)],
        out_shape=[
            jax.ShapeDtypeStruct((batch, 4 * hidden), c_prev.dtype),
            jax.ShapeDtypeStruct((batch, hidden), c_prev.dtype),
        ],
        interpret=True,
    )(tiled, c_prev, dh, dc_in)
    return _untile_gates(dgates_tiled, hidden, block_h), dc_prev


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _lstm_cell(gates, c_prev, block_h):
    return _cell_fwd_pallas(gates, c_prev, block_h)


def _lstm_cell_fwd(gates, c_prev, block_h):
    out = _cell_fwd_pallas(gates, c_prev, block_h)
    return out, (gates, c_prev)


def _lstm_cell_bwd(block_h, residuals, cotangents):
    gates, c_prev = residuals
    dh, dc_in = cotangents
    dgates, dc_prev = _cell_bwd_pallas(gates, c_prev, dh, dc_in, block_h)
    return dgates, dc_prev


_lstm_cell.defvjp(_lstm_cell_fwd, _lstm_cell_bwd)


def lstm_cell(gates: jnp.ndarray, c_prev: jnp.ndarray, block_h: int = DEFAULT_BLOCK_H):
    """Fused LSTM cell update via Pallas (differentiable).

    Args:
      gates: ``[B, 4H]`` pre-activations ``[i | f | g | o]``.
      c_prev: ``[B, H]`` previous cell state.
      block_h: hidden-dimension tile width (clamped to H; must divide H).

    Returns:
      ``(h_new, c_new)``, each ``[B, H]``, same dtype as the inputs.
    """
    batch, hidden = c_prev.shape
    assert gates.shape == (batch, 4 * hidden), (gates.shape, c_prev.shape)
    block_h = min(block_h, hidden)
    assert hidden % block_h == 0, f"hidden {hidden} not divisible by block_h {block_h}"
    return _lstm_cell(gates, c_prev, block_h)


def vmem_bytes(batch: int, block_h: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one forward grid step (DESIGN.md §Perf):
    gates block (4·block_h) + c_prev + h + c + ~3 temporaries."""
    per_col = 4 + 1 + 1 + 1 + 3
    return batch * block_h * per_col * dtype_bytes
