"""Pure-jnp oracle for the Pallas LSTM cell kernel.

The kernel computes one LSTM cell update from pre-activations:

    i, f, g, o = split(gates, 4, axis=-1)      # gates: [B, 4H]
    c_new = sigmoid(f + forget_bias) * c_prev + sigmoid(i) * tanh(g)
    h_new = sigmoid(o) * tanh(c_new)

This file is the correctness reference: ``test_kernel.py`` asserts the
Pallas kernel (interpret mode) matches it across a shape/dtype sweep, and
``model.py``'s scan uses the kernel while tests cross-check full-model
numerics against a ref-only model.
"""

import jax.nn
import jax.numpy as jnp

FORGET_BIAS = 1.0


def lstm_cell_ref(gates: jnp.ndarray, c_prev: jnp.ndarray):
    """Reference LSTM cell update.

    Args:
      gates: ``[B, 4H]`` pre-activations, laid out as ``[i | f | g | o]``.
      c_prev: ``[B, H]`` previous cell state.

    Returns:
      ``(h_new, c_new)``, each ``[B, H]``.
    """
    hidden = c_prev.shape[-1]
    assert gates.shape[-1] == 4 * hidden, (gates.shape, c_prev.shape)
    i = gates[..., 0 * hidden : 1 * hidden]
    f = gates[..., 1 * hidden : 2 * hidden]
    g = gates[..., 2 * hidden : 3 * hidden]
    o = gates[..., 3 * hidden : 4 * hidden]
    c_new = jax.nn.sigmoid(f + FORGET_BIAS) * c_prev + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new, c_new
