"""Layer-1 Pallas kernel: the PhasedLSTM time gate (Neil et al., 2016).

PhasedLSTM (the paper's second evaluation network) gates each unit's state
update by a rhythmic openness signal

    phi  = ((t - s) mod tau) / tau                 (phase, per unit)
    k    = 2*phi/r_on              if phi <  r_on/2
         = 2 - 2*phi/r_on          if phi <  r_on
         = alpha * phi             otherwise (leak)

    c    = k * c_cand + (1 - k) * c_prev
    h    = k * h_cand + (1 - k) * h_prev

One kernel invocation fuses the phase computation, the piecewise gate and
both blends, tiled along the hidden dimension like ``lstm_cell.py``. The
per-unit parameters ``tau``/``shift`` ride along as `[H]` vectors
broadcast over the batch.

Kept forward-only (the e2e example trains the plain LSTM); the oracle in
``ref.py`` and the hypothesis sweep in ``test_phased.py`` pin the
numerics, and ``aot.py`` exports it as the ``phased_gate`` artifact so the
Rust side can run it standalone.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_H = 128
DEFAULT_LEAK = 0.001


def _gate_kernel(c_cand_ref, h_cand_ref, c_prev_ref, h_prev_ref, tau_ref, shift_ref,
                 time_ref, out_c_ref, out_h_ref, *, r_on: float, leak: float):
    tau = tau_ref[...]  # [1, block_h]
    shift = shift_ref[...]
    t = time_ref[0, 0]
    phi = jnp.mod(t - shift, tau) / tau
    k = jnp.where(
        phi < r_on / 2.0,
        2.0 * phi / r_on,
        jnp.where(phi < r_on, 2.0 - 2.0 * phi / r_on, leak * phi),
    )  # [1, block_h], broadcasts over batch
    out_c_ref[...] = k * c_cand_ref[...] + (1.0 - k) * c_prev_ref[...]
    out_h_ref[...] = k * h_cand_ref[...] + (1.0 - k) * h_prev_ref[...]


@functools.partial(jax.jit, static_argnames=("r_on", "leak", "block_h"))
def phased_gate(
    c_cand: jnp.ndarray,
    h_cand: jnp.ndarray,
    c_prev: jnp.ndarray,
    h_prev: jnp.ndarray,
    tau: jnp.ndarray,
    shift: jnp.ndarray,
    time: jnp.ndarray,
    r_on: float = 0.05,
    leak: float = DEFAULT_LEAK,
    block_h: int = DEFAULT_BLOCK_H,
):
    """Apply the PhasedLSTM time gate.

    Args:
      c_cand, h_cand, c_prev, h_prev: ``[B, H]`` states.
      tau, shift: ``[H]`` per-unit period and phase shift (tau > 0).
      time: scalar array — the current timestamp.
      r_on: open-phase ratio.
      leak: closed-phase leak rate alpha.
      block_h: hidden tile width.

    Returns:
      ``(c_new, h_new)``, each ``[B, H]``.
    """
    batch, hidden = c_prev.shape
    for x in (c_cand, h_cand, h_prev):
        assert x.shape == (batch, hidden)
    assert tau.shape == (hidden,) and shift.shape == (hidden,)
    block_h = min(block_h, hidden)
    assert hidden % block_h == 0
    grid = (hidden // block_h,)

    def bh_index(j):
        return (0, j)

    spec_bh = pl.BlockSpec((batch, block_h), bh_index)
    spec_param = pl.BlockSpec((1, block_h), bh_index)
    spec_time = pl.BlockSpec((1, 1), lambda j: (0, 0))

    c, h = pl.pallas_call(
        functools.partial(_gate_kernel, r_on=r_on, leak=leak),
        grid=grid,
        in_specs=[spec_bh, spec_bh, spec_bh, spec_bh, spec_param, spec_param, spec_time],
        out_specs=[spec_bh, spec_bh],
        out_shape=[
            jax.ShapeDtypeStruct((batch, hidden), c_prev.dtype),
            jax.ShapeDtypeStruct((batch, hidden), c_prev.dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(c_cand, h_cand, c_prev, h_prev, tau.reshape(1, -1), shift.reshape(1, -1),
      time.reshape(1, 1))
    return c, h


def phased_gate_ref(c_cand, h_cand, c_prev, h_prev, tau, shift, time,
                    r_on: float = 0.05, leak: float = DEFAULT_LEAK):
    """Pure-jnp oracle for :func:`phased_gate`."""
    phi = jnp.mod(time - shift, tau) / tau  # [H]
    k = jnp.where(
        phi < r_on / 2.0,
        2.0 * phi / r_on,
        jnp.where(phi < r_on, 2.0 - 2.0 * phi / r_on, leak * phi),
    )
    c = k * c_cand + (1.0 - k) * c_prev
    h = k * h_cand + (1.0 - k) * h_prev
    return c, h
