"""Layer-2 JAX model: a byte-level LSTM language model.

Defines the forward pass (embedding → stacked LSTM layers whose cell math
is the Layer-1 Pallas kernel → output projection → softmax cross-entropy),
its SGD training step, and flat-parameter packing so the Rust runtime can
hold state as a single ``f32[P]`` buffer.

Build-time only: ``aot.py`` lowers ``train_step`` / ``forward_loss`` to HLO
text once; the Rust coordinator executes the artifacts via PJRT with no
Python on the request path.
"""

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels.lstm_cell import lstm_cell
from .kernels.ref import lstm_cell_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of the byte-LM used by the end-to-end example."""

    vocab: int = 256
    hidden: int = 256
    layers: int = 2
    seq: int = 32
    batch: int = 8
    lr: float = 0.5
    init_scale: float = 0.08
    # use the Pallas kernel (True) or the pure-jnp reference (False); the
    # test suite cross-checks both paths produce identical numerics
    use_pallas: bool = True


def param_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    """Ordered parameter dictionary: name → shape."""
    shapes = {"embed": (cfg.vocab, cfg.hidden)}
    for l in range(cfg.layers):
        # fused [x, h] → gates weight, per the standard LSTM formulation
        shapes[f"l{l}.w"] = (2 * cfg.hidden, 4 * cfg.hidden)
        shapes[f"l{l}.b"] = (4 * cfg.hidden,)
    shapes["head.w"] = (cfg.hidden, cfg.vocab)
    shapes["head.b"] = (cfg.vocab,)
    return shapes


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for s in param_shapes(cfg).values())


def unflatten(cfg: ModelConfig, flat: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Slice the flat ``f32[P]`` vector into named parameter tensors."""
    params = {}
    offset = 0
    for name, shape in param_shapes(cfg).items():
        size = 1
        for d in shape:
            size *= d
        params[name] = flat[offset : offset + size].reshape(shape)
        offset += size
    assert offset == flat.shape[0], (offset, flat.shape)
    return params


def init_params(cfg: ModelConfig, key) -> jnp.ndarray:
    """Flat uniform(-scale, scale) initialization (mirrored in Rust)."""
    n = param_count(cfg)
    return jax.random.uniform(key, (n,), jnp.float32, -cfg.init_scale, cfg.init_scale)


def _cell(cfg: ModelConfig, gates: jnp.ndarray, c_prev: jnp.ndarray):
    if cfg.use_pallas:
        return lstm_cell(gates, c_prev, block_h=min(128, cfg.hidden))
    return lstm_cell_ref(gates, c_prev)


def forward_loss(cfg: ModelConfig, flat_params: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Mean cross-entropy of next-byte prediction.

    Args:
      flat_params: ``f32[P]``.
      tokens: ``f32[batch, seq+1]`` byte codes (f32 for a uniform artifact
        ABI; cast to int inside).

    Returns:
      scalar loss.
    """
    p = unflatten(cfg, flat_params)
    toks = tokens.astype(jnp.int32)
    inputs = toks[:, :-1]  # [B, T]
    targets = toks[:, 1:]  # [B, T]
    x = p["embed"][inputs]  # [B, T, H]

    def step(carry, x_t):
        hs, cs = carry  # each [layers, B, H]
        new_hs, new_cs = [], []
        inp = x_t
        for l in range(cfg.layers):
            xh = jnp.concatenate([inp, hs[l]], axis=-1)  # [B, 2H]
            gates = xh @ p[f"l{l}.w"] + p[f"l{l}.b"]
            h_new, c_new = _cell(cfg, gates, cs[l])
            new_hs.append(h_new)
            new_cs.append(c_new)
            inp = h_new
        return (jnp.stack(new_hs), jnp.stack(new_cs)), inp

    h0 = jnp.zeros((cfg.layers, cfg.batch, cfg.hidden), jnp.float32)
    c0 = jnp.zeros_like(h0)
    xs = jnp.swapaxes(x, 0, 1)  # [T, B, H]
    _, outs = jax.lax.scan(step, (h0, c0), xs)
    outs = jnp.swapaxes(outs, 0, 1)  # [B, T, H]

    logits = outs @ p["head.w"] + p["head.b"]  # [B, T, V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def train_step(cfg: ModelConfig, flat_params: jnp.ndarray, tokens: jnp.ndarray):
    """One SGD step; returns ``(loss[1], new_params[P])``."""
    loss, grads = jax.value_and_grad(lambda fp: forward_loss(cfg, fp, tokens))(flat_params)
    new_params = flat_params - cfg.lr * grads
    return loss.reshape(1), new_params


@functools.partial(jax.jit, static_argnums=0)
def train_step_jit(cfg: ModelConfig, flat_params, tokens):
    return train_step(cfg, flat_params, tokens)


@functools.partial(jax.jit, static_argnums=0)
def forward_loss_jit(cfg: ModelConfig, flat_params, tokens):
    return (forward_loss(cfg, flat_params, tokens).reshape(1),)
