"""Pytest bootstrap: make `pytest python/tests/` work from the repo root
by putting the `python/` package directory on sys.path, and skip the
Pallas-kernel suite cleanly when its dependencies are absent.

The offline image ships no `jax` (see ROADMAP "Seed-test triage"): without
the guard below, collection dies with ImportError at every test module.
`collect_ignore_glob` makes pytest skip the directory instead of erroring.
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))

collect_ignore_glob = []
if importlib.util.find_spec("jax") is None or importlib.util.find_spec("hypothesis") is None:
    # python/tests needs jax (+ Pallas) and hypothesis; neither is in the
    # offline image, so ignore the whole tree rather than erroring out.
    collect_ignore_glob.append("python/tests/*")
